//! The native PDE residual layer: the paper's case-study physics built as
//! [`Graph`] nodes, trainable end-to-end under any AD strategy.
//!
//! A [`PdeResidual`] turns a DeepONet forward pass plus the strategy's
//! derivative builders into residual and boundary/initial loss nodes.  The
//! machinery mirrors `autodiff::zcs_demo` but generalises it to
//! d-dimensional coordinates and mixed partial derivatives:
//!
//! * [`ProblemBuilder`] owns the tape, the DeepONet weight leaves
//!   (`wb (q,h)`, `wb2 (h,k)`, `wt (d,h)`, `wt2 (h,k)`), the sensor leaf
//!   `p (m,q)`, and the named batch-feed registry;
//! * [`DerivBlock`] is one set of collocation points with pointwise
//!   derivatives `d^|a| u / dx0^a0 dx1^a1` available through
//!   [`DerivBlock::d`].  Under **ZCS** each coordinate gets a scalar shift
//!   leaf `z_c` (eq. 6) and derivatives come off the `omega = sum(a * u)`
//!   z-chain (eqs. 9-10); under **FuncLoop** each function takes its own
//!   nested reverse sweeps (eq. 4); under **DataVect** coordinates are
//!   tiled `m`-fold at the leaf end (eq. 5).  All three present results in
//!   one `(m, n)` layout, so each residual is written exactly once;
//! * value blocks evaluate the plain forward `u` at boundary/initial
//!   points (no derivative, hence no strategy split).
//!
//! Feed names are the Rust-native analogue of the artifact
//! `batch_schema`: [`BuiltProblem::feeds`] lists `(name, leaf)` pairs the
//! coordinator's `PdeBatcher` must produce per step (checked by name).
//!
//! Implemented problems (Table 1 of the paper; Stokes remains
//! artifact-only for now):
//!
//! | problem            | residual (graph form)                               |
//! |--------------------|-----------------------------------------------------|
//! | antiderivative     | `u_x - f`                                           |
//! | reaction_diffusion | `u_t - D u_xx + k u^2 - f`         (eq. 16)         |
//! | burgers            | `u_t + u u_x - nu u_xx`            (eq. 17)         |
//! | kirchhoff          | `D (u_xxxx + 2 u_xxyy + u_yyyy) - q` (eq. 18, scaled by the rigidity so the target stays O(1)) |

use crate::autodiff::graph::{Graph, NodeId};
use crate::autodiff::zcs_demo::Strategy;
use crate::pde::ProblemKind;
use crate::rng::Pcg64;
use crate::tensor::Tensor;
use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;

/// DeepONet dimensions for the native residual layer.
#[derive(Clone, Copy, Debug)]
pub struct NetDims {
    /// branch sensors (the paper's Q)
    pub q: usize,
    /// hidden width of both MLPs
    pub hidden: usize,
    /// latent combine dimension (the DeepONet K)
    pub k: usize,
    /// coordinate dimension of the trunk input (1 or 2 here)
    pub coord_dim: usize,
}

/// Collocation-block sizes for one problem build.
#[derive(Clone, Copy, Debug)]
pub struct BlockSizes {
    /// interior (residual) points per batch (the paper's N)
    pub n_in: usize,
    /// points per boundary/initial block
    pub n_bc: usize,
}

/// Builder state shared by every block of one problem graph.
pub struct ProblemBuilder {
    /// the growing tape; residual implementations append ops directly
    pub g: Graph,
    strategy: Strategy,
    m: usize,
    dims: NetDims,
    /// wb (q,h), wb2 (h,k), wt (d,h), wt2 (h,k)
    weights: [NodeId; 4],
    /// sensor leaf (m, q)
    p: NodeId,
    /// branch(p) (m, k), shared by every non-tiled block
    branch_p: NodeId,
    /// prepended to every registered feed name: `""` for the unlaned
    /// builder, `"l{lane}."` for a lane block
    prefix: String,
    /// denominator of the function mean in [`Self::mean_sq`].  For the
    /// unlaned builder this equals `m`; for a lane block it is the
    /// *global* function count, so lane losses are partial sums that add
    /// (never rescale) into the total loss.
    loss_norm_m: usize,
    feeds: Vec<(String, NodeId)>,
    extra_inputs: Vec<(NodeId, Tensor)>,
}

impl ProblemBuilder {
    pub fn new(strategy: Strategy, m: usize, dims: NetDims) -> Self {
        let mut g = Graph::new();
        let wb = g.input(&[dims.q, dims.hidden]);
        let wb2 = g.input(&[dims.hidden, dims.k]);
        let wt = g.input(&[dims.coord_dim, dims.hidden]);
        let wt2 = g.input(&[dims.hidden, dims.k]);
        Self::with_shared_weights(g, strategy, m, dims, [wb, wb2, wt, wt2], String::new(), m)
    }

    /// A builder over an existing tape and weight leaves -- the lane-block
    /// constructor ([`build_lane_training_problem`]).  `m` is this lane's
    /// own row count, `loss_norm_m` the global function count, and
    /// `prefix` namespaces the lane's feed names.  The lane's sensor leaf
    /// and branch trunk are private to the lane; only the four weight
    /// leaves are shared, so lane subgraphs stay fully independent.
    pub fn with_shared_weights(
        mut g: Graph,
        strategy: Strategy,
        m: usize,
        dims: NetDims,
        weights: [NodeId; 4],
        prefix: String,
        loss_norm_m: usize,
    ) -> Self {
        let [wb, wb2, _, _] = weights;
        let p = g.input(&[m, dims.q]);
        let h = g.matmul(p, wb);
        let a = g.tanh(h);
        let branch_p = g.matmul(a, wb2);
        Self {
            g,
            strategy,
            m,
            dims,
            weights,
            p,
            branch_p,
            prefix,
            loss_norm_m,
            feeds: Vec::new(),
            extra_inputs: Vec::new(),
        }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn strategy(&self) -> Strategy {
        self.strategy
    }

    pub fn coord_dim(&self) -> usize {
        self.dims.coord_dim
    }

    /// Named batch feeds registered so far.
    pub fn feeds(&self) -> &[(String, NodeId)] {
        &self.feeds
    }

    /// Constant-valued leaves (ZCS z and a) to feed at evaluation time.
    pub fn extra_inputs(&self) -> &[(NodeId, Tensor)] {
        &self.extra_inputs
    }

    /// Branch MLP on an arbitrary sensor matrix (rows, q) -> (rows, k).
    fn branch_of(&mut self, pin: NodeId) -> NodeId {
        let [wb, wb2, _, _] = self.weights;
        let h = self.g.matmul(pin, wb);
        let a = self.g.tanh(h);
        self.g.matmul(a, wb2)
    }

    /// Trunk MLP on a coordinate matrix (rows, d) -> (rows, k).
    fn trunk(&mut self, xin: NodeId) -> NodeId {
        let [_, _, wt, wt2] = self.weights;
        let h = self.g.matmul(xin, wt);
        let a = self.g.tanh(h);
        self.g.matmul(a, wt2)
    }

    /// Assemble the (rows, d) trunk input from per-dimension (rows, 1)
    /// columns via constant one-hot embeddings (no concat op needed).
    fn combine_coords(&mut self, cols: &[NodeId]) -> NodeId {
        let dim = self.dims.coord_dim;
        assert_eq!(cols.len(), dim);
        if dim == 1 {
            return cols[0];
        }
        let mut acc: Option<NodeId> = None;
        for (c, &col) in cols.iter().enumerate() {
            let mut e = Tensor::zeros(&[1, dim]);
            e.data_mut()[c] = 1.0;
            let ec = self.g.constant(e);
            let term = self.g.matmul(col, ec); // (rows, d)
            acc = Some(match acc {
                Some(prev) => self.g.add(prev, term),
                None => term,
            });
        }
        acc.expect("dim >= 1")
    }

    /// The DeepONet field on (already shifted / tiled) coordinate columns:
    /// `(m, rows)` under ZCS / FuncLoop, `(rows, 1)` under DataVect.
    fn deeponet_field(&mut self, cols: &[NodeId]) -> NodeId {
        let rows = self.g.shape(cols[0])[0];
        let tin = self.combine_coords(cols);
        let t = self.trunk(tin);
        match self.strategy {
            Strategy::DataVect => {
                let n = rows / self.m;
                let rp = self.g.constant(tile_functions(self.m, n));
                let ph = self.g.matmul(rp, self.p); // (m n, q)
                let b = self.branch_of(ph); // (m n, k)
                let bt = self.g.mul(b, t);
                self.g.sum_axis(bt, 1) // (m n, 1)
            }
            _ => self.g.matmul_nt(self.branch_p, t), // (m, rows)
        }
    }

    /// Register a named batch-fed leaf (aux fields, targets).
    pub fn aux(&mut self, name: &str, shape: &[usize]) -> NodeId {
        let id = self.g.input(shape);
        self.feeds.push((format!("{}{name}", self.prefix), id));
        id
    }

    /// A value-only point block: plain forward `u` of shape (m, n) at `n`
    /// batch-fed points.  Registers feeds `{name}.x{c}` of shape (n, 1).
    pub fn value_block(&mut self, name: &str, n: usize) -> (Vec<NodeId>, NodeId) {
        let dim = self.dims.coord_dim;
        let mut coords = Vec::with_capacity(dim);
        for c in 0..dim {
            let x = self.g.input(&[n, 1]);
            self.feeds.push((format!("{}{name}.x{c}", self.prefix), x));
            coords.push(x);
        }
        let tin = self.combine_coords(&coords);
        let t = self.trunk(tin);
        let u = self.g.matmul_nt(self.branch_p, t); // (m, n)
        (coords, u)
    }

    /// A derivative-capable point block over the DeepONet field.
    pub fn deriv_block(&mut self, name: &str, n: usize) -> DerivBlock {
        self.deriv_block_with(name, n, &mut |b, cols| b.deeponet_field(cols))
    }

    /// A derivative-capable point block over an arbitrary field.  The
    /// closure receives the per-dimension coordinate columns *after* the
    /// strategy's preprocessing (ZCS shift / DataVect tiling) and must
    /// return `(m, rows)` under ZCS / FuncLoop or `(rows, 1)` under
    /// DataVect.  Used directly by the residual-consistency tests to
    /// differentiate analytic reference fields.
    pub fn deriv_block_with(
        &mut self,
        name: &str,
        n: usize,
        field: &mut dyn FnMut(&mut ProblemBuilder, &[NodeId]) -> NodeId,
    ) -> DerivBlock {
        let dim = self.dims.coord_dim;
        let m = self.m;
        let mut coords = Vec::with_capacity(dim);
        for c in 0..dim {
            let x = self.g.input(&[n, 1]);
            self.feeds.push((format!("{}{name}.x{c}", self.prefix), x));
            coords.push(x);
        }
        match self.strategy {
            Strategy::Zcs => {
                // eq. (6): shift each coordinate by its own scalar leaf
                let mut zs = Vec::with_capacity(dim);
                let mut shifted = Vec::with_capacity(dim);
                for &x in &coords {
                    let z = self.g.input(&[]);
                    let zb = self.g.broadcast(z, &[n, 1]);
                    let xz = self.g.add(x, zb);
                    self.extra_inputs.push((z, Tensor::new(&[], vec![0.0])));
                    zs.push(z);
                    shifted.push(xz);
                }
                let u = field(self, &shifted);
                assert_eq!(self.g.shape(u), &[m, n], "zcs field layout");
                // eq. (9): omega = sum(a * u) with the dummy leaf a
                let a = self.g.input(&[m, n]);
                self.extra_inputs.push((a, Tensor::full(&[m, n], 1.0)));
                let au = self.g.mul(a, u);
                let omega = self.g.sum_all(au);
                let mut zcache = HashMap::new();
                zcache.insert(vec![0usize; dim], omega);
                DerivBlock {
                    m,
                    n,
                    dim,
                    coords,
                    u_mn: u,
                    inner: BlockInner::Zcs { zs, a, zcache, dcache: HashMap::new() },
                }
            }
            Strategy::FuncLoop => {
                let u = field(self, &coords);
                assert_eq!(self.g.shape(u), &[m, n], "funcloop field layout");
                DerivBlock {
                    m,
                    n,
                    dim,
                    coords,
                    u_mn: u,
                    inner: BlockInner::FuncLoop { cache: HashMap::new(), dcache: HashMap::new() },
                }
            }
            Strategy::DataVect => {
                // eq. (5): tile the coordinates to m*n pointwise rows
                let rx = self.g.constant(tile_points(m, n));
                let xh: Vec<NodeId> = coords.iter().map(|&x| self.g.matmul(rx, x)).collect();
                let u_rows = field(self, &xh);
                assert_eq!(self.g.shape(u_rows), &[m * n, 1], "datavect field layout");
                let u = self.g.reshape_of(u_rows, &[m, n]);
                DerivBlock {
                    m,
                    n,
                    dim,
                    coords,
                    u_mn: u,
                    inner: BlockInner::DataVect {
                        u_rows,
                        xh,
                        cache: HashMap::new(),
                        dcache: HashMap::new(),
                    },
                }
            }
        }
    }

    /// Mean of squared entries of an (m, n) node -- the loss primitive
    /// (row means via the axis-aware reduction, then the function mean).
    /// The function mean divides by `loss_norm_m` (the global M), so a
    /// lane block contributes `sum(row_means) / M_global` and lane losses
    /// fold into the total by pure addition.
    pub fn mean_sq(&mut self, r: NodeId) -> NodeId {
        let r2 = self.g.square(r);
        let row_means = self.g.mean_axis(r2, 1); // (m, 1)
        let s = self.g.sum_all(row_means);
        self.g.scale(s, 1.0 / self.loss_norm_m as f64)
    }
}

/// `(m n, m)` selector replicating each function row n times (eq. 5).
fn tile_functions(m: usize, n: usize) -> Tensor {
    let mut rp = Tensor::zeros(&[m * n, m]);
    for i in 0..m {
        for j in 0..n {
            rp.data_mut()[(i * n + j) * m + i] = 1.0;
        }
    }
    rp
}

/// `(m n, n)` selector replicating the point set m times (eq. 5).
fn tile_points(m: usize, n: usize) -> Tensor {
    let mut rx = Tensor::zeros(&[m * n, n]);
    for i in 0..m {
        for j in 0..n {
            rx.data_mut()[(i * n + j) * n + j] = 1.0;
        }
    }
    rx
}

/// One collocation-point block with strategy-built pointwise derivatives.
pub struct DerivBlock {
    m: usize,
    n: usize,
    dim: usize,
    /// unshifted coordinate leaves, (n, 1) per dimension (batch-fed)
    coords: Vec<NodeId>,
    /// the field in the normalized (m, n) layout
    u_mn: NodeId,
    inner: BlockInner,
}

enum BlockInner {
    Zcs {
        /// one shift scalar per coordinate dimension
        zs: Vec<NodeId>,
        /// the eq.-9 dummy-summation leaf (m, n)
        a: NodeId,
        /// z-chain scalars keyed by partial derivative orders
        zcache: HashMap<Vec<usize>, NodeId>,
        /// finished (m, n) derivatives keyed by orders
        dcache: HashMap<Vec<usize>, NodeId>,
    },
    FuncLoop {
        /// per-function chain nodes keyed by (function, orders); the
        /// all-zero key holds the scalar root, others the (n, 1) rows
        cache: HashMap<(usize, Vec<usize>), NodeId>,
        dcache: HashMap<Vec<usize>, NodeId>,
    },
    DataVect {
        /// tiled field rows (m n, 1)
        u_rows: NodeId,
        /// tiled coordinate columns (m n, 1) per dimension
        xh: Vec<NodeId>,
        /// chain nodes (m n, 1) keyed by orders
        cache: HashMap<Vec<usize>, NodeId>,
        dcache: HashMap<Vec<usize>, NodeId>,
    },
}

impl DerivBlock {
    /// The field itself, (m, n).
    pub fn u(&self) -> NodeId {
        self.u_mn
    }

    /// The unshifted coordinate leaves, one (n, 1) input per dimension.
    pub fn coords(&self) -> &[NodeId] {
        &self.coords
    }

    /// Pointwise mixed partial `d^|orders| u / prod_c dx_c^orders[c]` in
    /// the (m, n) layout.  Chains are cached, so e.g. `u_xx` extends the
    /// tape built for `u_x` instead of rebuilding it.
    pub fn d(&mut self, b: &mut ProblemBuilder, orders: &[usize]) -> NodeId {
        assert_eq!(orders.len(), self.dim, "one order per coordinate dimension");
        let total: usize = orders.iter().sum();
        assert!(total >= 1, "derivative order must be >= 1");
        let (m, n, dim) = (self.m, self.n, self.dim);
        let coords = self.coords.clone();
        match &mut self.inner {
            BlockInner::Zcs { zs, a, zcache, dcache } => {
                if let Some(&v) = dcache.get(orders) {
                    return v;
                }
                // eq. (10): walk the z-chain (each step is scalar -> scalar,
                // so no re-rooting), then one d/da pass back to (m, n)
                let mut key = vec![0usize; dim];
                let mut cur = *zcache.get(&key).expect("omega seeds the chain");
                for c in (0..dim).rev() {
                    for _ in 0..orders[c] {
                        key[c] += 1;
                        cur = match zcache.get(&key) {
                            Some(&v) => v,
                            None => {
                                let d = b.g.grad(cur, &[zs[c]])[0];
                                zcache.insert(key.clone(), d);
                                d
                            }
                        };
                    }
                }
                let da = b.g.grad(cur, &[*a])[0]; // (m, n)
                dcache.insert(orders.to_vec(), da);
                da
            }
            BlockInner::FuncLoop { cache, dcache } => {
                if let Some(&v) = dcache.get(orders) {
                    return v;
                }
                let u = self.u_mn;
                let mut acc: Option<NodeId> = None;
                for i in 0..m {
                    // eq. (4): one nested reverse chain per function
                    let mut key = (i, vec![0usize; dim]);
                    let mut cur = match cache.get(&key) {
                        Some(&v) => v,
                        None => {
                            let mut e = Tensor::zeros(&[1, m]);
                            e.data_mut()[i] = 1.0;
                            let ei = b.g.constant(e);
                            let row = b.g.matmul(ei, u); // (1, n)
                            let root = b.g.sum_all(row);
                            cache.insert(key.clone(), root);
                            root
                        }
                    };
                    let mut at_root = true; // cur is the scalar sum_j u_ij
                    for c in (0..dim).rev() {
                        for _ in 0..orders[c] {
                            key.1[c] += 1;
                            cur = match cache.get(&key) {
                                Some(&v) => v,
                                None => {
                                    // u_ij depends on point j only, so
                                    // re-rooting via sum_all keeps the
                                    // nested derivative pointwise
                                    let root = if at_root { cur } else { b.g.sum_all(cur) };
                                    let d = b.g.grad(root, &[coords[c]])[0]; // (n, 1)
                                    cache.insert(key.clone(), d);
                                    d
                                }
                            };
                            at_root = false;
                        }
                    }
                    let dt = b.g.transpose_of(cur); // (1, n)
                    let mut ecol = Tensor::zeros(&[m, 1]);
                    ecol.data_mut()[i] = 1.0;
                    let ecol = b.g.constant(ecol);
                    let term = b.g.matmul(ecol, dt); // (m, n), row i only
                    acc = Some(match acc {
                        Some(prev) => b.g.add(prev, term),
                        None => term,
                    });
                }
                let out = acc.expect("m >= 1");
                dcache.insert(orders.to_vec(), out);
                out
            }
            BlockInner::DataVect { u_rows, xh, cache, dcache } => {
                if let Some(&v) = dcache.get(orders) {
                    return v;
                }
                let mut key = vec![0usize; dim];
                let mut cur = *u_rows;
                for c in (0..dim).rev() {
                    for _ in 0..orders[c] {
                        key[c] += 1;
                        cur = match cache.get(&key) {
                            Some(&v) => v,
                            None => {
                                // tiled rows are independent copies: the
                                // summed root's gradient is pointwise
                                let root = b.g.sum_all(cur);
                                let d = b.g.grad(root, &[xh[c]])[0]; // (m n, 1)
                                cache.insert(key.clone(), d);
                                d
                            }
                        };
                    }
                }
                let out = b.g.reshape_of(cur, &[m, n]);
                dcache.insert(orders.to_vec(), out);
                out
            }
        }
    }
}

/// Loss nodes one residual build produces.
pub struct ResidualLosses {
    /// mean squared PDE residual over the interior block (scalar)
    pub loss_pde: NodeId,
    /// summed boundary/initial losses (scalar)
    pub loss_bc: NodeId,
    /// the raw interior residual (m, n), exposed for consistency tests
    pub residual: NodeId,
}

/// A problem's physics: residual + boundary/initial losses as graph nodes.
pub trait PdeResidual {
    fn kind(&self) -> ProblemKind;
    fn coord_dim(&self) -> usize;
    /// Append the losses to `b`'s tape.  Feed registration order defines
    /// the batch contract (see [`BuiltProblem::feeds`]).
    fn build_losses(&self, b: &mut ProblemBuilder, sizes: BlockSizes) -> ResidualLosses;
}

/// `du/dx = f` on (0, 1) -- no boundary term (the operator is learned up
/// to the derivative, exactly like the original native demo).
pub struct Antiderivative;

impl PdeResidual for Antiderivative {
    fn kind(&self) -> ProblemKind {
        ProblemKind::Antiderivative
    }

    fn coord_dim(&self) -> usize {
        1
    }

    fn build_losses(&self, b: &mut ProblemBuilder, sizes: BlockSizes) -> ResidualLosses {
        let m = b.m();
        let mut blk = b.deriv_block("in", sizes.n_in);
        let ux = blk.d(b, &[1]);
        let f = b.aux("in.f", &[m, sizes.n_in]);
        let r = b.g.sub(ux, f);
        let loss_pde = b.mean_sq(r);
        let loss_bc = b.g.constant(Tensor::new(&[], vec![0.0]));
        ResidualLosses { loss_pde, loss_bc, residual: r }
    }
}

/// Reaction-diffusion `u_t - D u_xx + k u^2 - f = 0` on the unit square
/// with `u(x, 0) = 0` and `u(0, t) = u(1, t) = 0` (paper eq. 16).
pub struct ReactionDiffusionResidual {
    pub diff_coef: f64,
    pub react_coef: f64,
}

impl Default for ReactionDiffusionResidual {
    fn default() -> Self {
        let kind = ProblemKind::ReactionDiffusion;
        Self {
            diff_coef: kind.constant("D").expect("paper constant D"),
            react_coef: kind.constant("k").expect("paper constant k"),
        }
    }
}

impl PdeResidual for ReactionDiffusionResidual {
    fn kind(&self) -> ProblemKind {
        ProblemKind::ReactionDiffusion
    }

    fn coord_dim(&self) -> usize {
        2
    }

    fn build_losses(&self, b: &mut ProblemBuilder, sizes: BlockSizes) -> ResidualLosses {
        let m = b.m();
        let mut blk = b.deriv_block("in", sizes.n_in);
        let u = blk.u();
        let ut = blk.d(b, &[0, 1]);
        let uxx = blk.d(b, &[2, 0]);
        let f = b.aux("in.f", &[m, sizes.n_in]);
        let du = b.g.scale(uxx, self.diff_coef);
        let r1 = b.g.sub(ut, du);
        let u2 = b.g.square(u);
        let ku2 = b.g.scale(u2, self.react_coef);
        let r2 = b.g.add(r1, ku2);
        let r = b.g.sub(r2, f);
        let loss_pde = b.mean_sq(r);
        // u = 0 on the initial line and the two spatial walls
        let (_, u_ic) = b.value_block("ic", sizes.n_bc);
        let l_ic = b.mean_sq(u_ic);
        let (_, u_bc) = b.value_block("bc", sizes.n_bc);
        let l_bc = b.mean_sq(u_bc);
        let loss_bc = b.g.add(l_ic, l_bc);
        ResidualLosses { loss_pde, loss_bc, residual: r }
    }
}

/// Periodic Burgers `u_t + u u_x - nu u_xx = 0` with `u(x, 0) = u0(x)`
/// and `u(0, t) = u(1, t)` (paper eq. 17).
pub struct BurgersResidual {
    pub viscosity: f64,
}

impl Default for BurgersResidual {
    fn default() -> Self {
        Self { viscosity: ProblemKind::Burgers.constant("nu").expect("paper constant nu") }
    }
}

impl PdeResidual for BurgersResidual {
    fn kind(&self) -> ProblemKind {
        ProblemKind::Burgers
    }

    fn coord_dim(&self) -> usize {
        2
    }

    fn build_losses(&self, b: &mut ProblemBuilder, sizes: BlockSizes) -> ResidualLosses {
        let m = b.m();
        let mut blk = b.deriv_block("in", sizes.n_in);
        let u = blk.u();
        let ut = blk.d(b, &[0, 1]);
        let ux = blk.d(b, &[1, 0]);
        let uxx = blk.d(b, &[2, 0]);
        let uux = b.g.mul(u, ux);
        let adv = b.g.add(ut, uux);
        let visc = b.g.scale(uxx, self.viscosity);
        let nvisc = b.g.neg(visc);
        let r = b.g.add(adv, nvisc);
        let loss_pde = b.mean_sq(r);
        // initial condition u(x, 0) = u0(x)
        let (_, u_ic) = b.value_block("ic", sizes.n_bc);
        let u0 = b.aux("ic.u0", &[m, sizes.n_bc]);
        let ric = b.g.sub(u_ic, u0);
        let l_ic = b.mean_sq(ric);
        // periodicity: u at (0, t) equals u at (1, t) for shared t's
        let (_, u_left) = b.value_block("left", sizes.n_bc);
        let (_, u_right) = b.value_block("right", sizes.n_bc);
        let rper = b.g.sub(u_left, u_right);
        let l_per = b.mean_sq(rper);
        let loss_bc = b.g.add(l_ic, l_per);
        ResidualLosses { loss_pde, loss_bc, residual: r }
    }
}

/// Kirchhoff-Love plate `D (u_xxxx + 2 u_xxyy + u_yyyy) = q` on the unit
/// square, simply supported: `u = 0` on every edge, `u_xx = 0` on the
/// x-walls and `u_yy = 0` on the y-walls (paper eq. 18; the residual is
/// kept in the rigidity-scaled form so its magnitude tracks the load).
pub struct KirchhoffResidual {
    pub rigidity: f64,
}

impl Default for KirchhoffResidual {
    fn default() -> Self {
        Self {
            rigidity: ProblemKind::Kirchhoff.constant("D_flex").expect("paper constant D_flex"),
        }
    }
}

impl PdeResidual for KirchhoffResidual {
    fn kind(&self) -> ProblemKind {
        ProblemKind::Kirchhoff
    }

    fn coord_dim(&self) -> usize {
        2
    }

    fn build_losses(&self, b: &mut ProblemBuilder, sizes: BlockSizes) -> ResidualLosses {
        let m = b.m();
        let mut blk = b.deriv_block("in", sizes.n_in);
        let d4x = blk.d(b, &[4, 0]);
        let d22 = blk.d(b, &[2, 2]);
        let d4y = blk.d(b, &[0, 4]);
        let q = b.aux("in.q", &[m, sizes.n_in]);
        let two_d22 = b.g.scale(d22, 2.0);
        let s1 = b.g.add(d4x, two_d22);
        let bih = b.g.add(s1, d4y);
        let dbih = b.g.scale(bih, self.rigidity);
        let r = b.g.sub(dbih, q);
        let loss_pde = b.mean_sq(r);
        // deflection-free edges
        let (_, u_bnd) = b.value_block("bnd", sizes.n_bc);
        let l_u = b.mean_sq(u_bnd);
        // moment-free edges: u_xx = 0 where x is pinned, u_yy = 0 where y is
        let mut mx = b.deriv_block("mx", sizes.n_bc);
        let uxx_b = mx.d(b, &[2, 0]);
        let l_mx = b.mean_sq(uxx_b);
        let mut my = b.deriv_block("my", sizes.n_bc);
        let uyy_b = my.d(b, &[0, 2]);
        let l_my = b.mean_sq(uyy_b);
        let lm = b.g.add(l_mx, l_my);
        let loss_bc = b.g.add(l_u, lm);
        ResidualLosses { loss_pde, loss_bc, residual: r }
    }
}

/// The native residual for a problem, if implemented (Stokes and the
/// high-order family remain artifact-only).
pub fn residual_for(kind: ProblemKind) -> Option<Box<dyn PdeResidual>> {
    match kind {
        ProblemKind::Antiderivative => Some(Box::new(Antiderivative)),
        ProblemKind::ReactionDiffusion => Some(Box::new(ReactionDiffusionResidual::default())),
        ProblemKind::Burgers => Some(Box::new(BurgersResidual::default())),
        ProblemKind::Kirchhoff => Some(Box::new(KirchhoffResidual::default())),
        _ => None,
    }
}

/// A fully built training-step graph for one (problem, strategy) pair.
pub struct BuiltProblem {
    pub graph: Graph,
    /// `[loss, loss_pde, loss_bc, d loss/d wb, d wb2, d wt, d wt2]`
    pub outputs: Vec<NodeId>,
    /// wb (q,h), wb2 (h,k), wt (d,h), wt2 (h,k)
    pub weight_ids: Vec<NodeId>,
    /// sensor leaf (m, q)
    pub p: NodeId,
    /// named batch feeds, in registration order (the native batch schema)
    pub feeds: Vec<(String, NodeId)>,
    /// constant-valued leaves (ZCS z and a), fed every step
    pub extra_inputs: Vec<(NodeId, Tensor)>,
    /// the raw interior residual (m, n)
    pub residual: NodeId,
    pub coord_dim: usize,
}

/// The trainer-canonical weight initialization for a built problem: draw
/// order (wb, wb2, wt, wt2) from stream 2 of the run seed, each matrix
/// scaled by `1/sqrt(fan_in)`.  [`NativeTrainer`], the benches and the
/// differential tests all share this one definition so they can never
/// drift apart.
///
/// [`NativeTrainer`]: crate::coordinator::native::NativeTrainer
pub fn init_problem_weights(built: &BuiltProblem, seed: u64) -> Vec<Tensor> {
    init_weights(&built.graph, &built.weight_ids, seed)
}

/// [`init_problem_weights`] for an arbitrary (graph, weight-leaf) pair --
/// the lane-blocked builds share it, and because the draw order depends
/// only on the four weight *shapes* (identical in every decomposition),
/// lane-blocked and unlaned builds start from bit-identical weights.
pub fn init_weights(graph: &Graph, weight_ids: &[NodeId], seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg64::new(seed, 2);
    weight_ids
        .iter()
        .map(|&id| {
            let shape = graph.shape(id).to_vec();
            let n: usize = shape.iter().product();
            Tensor::new(&shape, rng.normals(n)).scale(1.0 / (shape[0] as f64).sqrt())
        })
        .collect()
}

/// Build the full training-step graph: forward, strategy derivatives,
/// residual + boundary losses, weight gradients.
pub fn build_training_problem(
    kind: ProblemKind,
    strategy: Strategy,
    m: usize,
    q: usize,
    hidden: usize,
    k: usize,
    sizes: BlockSizes,
) -> Result<BuiltProblem> {
    let residual = residual_for(kind).ok_or_else(|| {
        anyhow!(
            "problem {:?} has no native residual; native problems: antiderivative, \
             reaction_diffusion, burgers, kirchhoff",
            kind.name()
        )
    })?;
    ensure!(m >= 1 && q >= 1 && sizes.n_in >= 1 && sizes.n_bc >= 1, "empty problem");
    let dims = NetDims { q, hidden, k, coord_dim: residual.coord_dim() };
    let mut b = ProblemBuilder::new(strategy, m, dims);
    let parts = residual.build_losses(&mut b, sizes);
    let loss = b.g.add(parts.loss_pde, parts.loss_bc);
    let weight_ids = b.weights.to_vec();
    let grads = b.g.grad(loss, &weight_ids);
    let mut outputs = vec![loss, parts.loss_pde, parts.loss_bc];
    outputs.extend(grads);
    Ok(BuiltProblem {
        graph: b.g,
        outputs,
        weight_ids,
        p: b.p,
        feeds: b.feeds,
        extra_inputs: b.extra_inputs,
        residual: parts.residual,
        coord_dim: dims.coord_dim,
    })
}

/// Upper bound on the canonical lane count ([`lane_count`]).
pub const MAX_LANES: usize = 4;

/// The canonical lane count for an `m`-function problem: `min(4, m)`.
///
/// The function dimension is always decomposed into this many lane
/// blocks *regardless of the replica count* -- replicas only change
/// which process computes which lane.  Because the decomposition (and
/// the fixed ascending-lane fold order) never varies with N, an
/// N-replica run is bit-identical to a single-replica run of the same
/// problem (see `rust/tests/replica_train.rs`).
pub fn lane_count(m: usize) -> usize {
    MAX_LANES.min(m.max(1))
}

/// Function-row range `[start, end)` of global lane `lane` out of
/// `n_lanes` over `m` rows: the standard balanced split
/// `[m*l/L, m*(l+1)/L)`, which covers `0..m` contiguously and keeps
/// every lane non-empty whenever `n_lanes <= m`.
pub fn lane_bounds(m: usize, n_lanes: usize, lane: usize) -> (usize, usize) {
    assert!(n_lanes >= 1 && lane < n_lanes, "lane {lane} of {n_lanes}");
    (m * lane / n_lanes, m * (lane + 1) / n_lanes)
}

/// One lane block of a [`BuiltLaneProblem`]: the lane's private leaves.
pub struct LaneBlock {
    /// global lane index in `0..n_lanes`
    pub lane: usize,
    /// function-row range `[start, end)` this lane covers in the global
    /// batch (its sensor and m-rowed aux feeds are these rows)
    pub rows: (usize, usize),
    /// the lane's sensor leaf (rows, q)
    pub p: NodeId,
    /// the lane's named batch feeds, names prefixed `l{lane}.`
    pub feeds: Vec<(String, NodeId)>,
    /// the lane's constant-valued leaves (ZCS z and a)
    pub extra_inputs: Vec<(NodeId, Tensor)>,
}

/// A lane-blocked training-step graph: one independent residual subgraph
/// per *local* lane (sharing only the four weight leaves), with per-lane
/// losses and per-lane weight gradients as outputs.
pub struct BuiltLaneProblem {
    pub graph: Graph,
    /// lane-major losses then weight-major per-lane gradients:
    /// `[l0.loss, l0.pde, l0.bc, l1.loss, ..., wb@l0, wb@l1, ...,
    /// wb2@l0, ...]` where `l0 < l1 < ...` are the local lanes
    pub outputs: Vec<NodeId>,
    /// wb (q,h), wb2 (h,k), wt (d,h), wt2 (h,k)
    pub weight_ids: Vec<NodeId>,
    /// the local lane blocks, ascending by global lane index
    pub lanes: Vec<LaneBlock>,
    /// total lanes in the canonical decomposition (across all replicas)
    pub n_lanes: usize,
    pub coord_dim: usize,
}

impl BuiltLaneProblem {
    /// Index of the first gradient output (after the 3-per-lane losses).
    pub fn grads_start(&self) -> usize {
        3 * self.lanes.len()
    }
}

/// Build the lane-blocked training-step graph for the local lanes of one
/// replica (or all lanes, for a single-replica run).  Each lane is a
/// fully self-contained copy of the problem over its own function rows:
/// its losses are normalized by the *global* M (so lane losses fold into
/// the total by pure addition, in ascending lane order) and its weight
/// gradients are the lane's exact contribution to the global gradient
/// (folded by the in-Program all-reduce, same fixed order).
pub fn build_lane_training_problem(
    kind: ProblemKind,
    strategy: Strategy,
    m: usize,
    local_lanes: &[usize],
    q: usize,
    hidden: usize,
    k: usize,
    sizes: BlockSizes,
) -> Result<BuiltLaneProblem> {
    let residual = residual_for(kind).ok_or_else(|| {
        anyhow!(
            "problem {:?} has no native residual; native problems: antiderivative, \
             reaction_diffusion, burgers, kirchhoff",
            kind.name()
        )
    })?;
    ensure!(m >= 1 && q >= 1 && sizes.n_in >= 1 && sizes.n_bc >= 1, "empty problem");
    let n_lanes = lane_count(m);
    ensure!(!local_lanes.is_empty(), "a replica owns at least one lane");
    ensure!(local_lanes.windows(2).all(|w| w[0] < w[1]), "local lanes must ascend");
    ensure!(*local_lanes.last().unwrap() < n_lanes, "lane out of range (n_lanes {n_lanes})");
    let dims = NetDims { q, hidden, k, coord_dim: residual.coord_dim() };
    let mut g = Graph::new();
    let wb = g.input(&[dims.q, dims.hidden]);
    let wb2 = g.input(&[dims.hidden, dims.k]);
    let wt = g.input(&[dims.coord_dim, dims.hidden]);
    let wt2 = g.input(&[dims.hidden, dims.k]);
    let weight_ids = vec![wb, wb2, wt, wt2];
    let mut lanes = Vec::with_capacity(local_lanes.len());
    let mut losses = Vec::with_capacity(3 * local_lanes.len());
    let mut lane_grads: Vec<Vec<NodeId>> = Vec::with_capacity(local_lanes.len());
    for &lane in local_lanes {
        let (r0, r1) = lane_bounds(m, n_lanes, lane);
        let mut b = ProblemBuilder::with_shared_weights(
            g,
            strategy,
            r1 - r0,
            dims,
            [wb, wb2, wt, wt2],
            format!("l{lane}."),
            m,
        );
        let parts = residual.build_losses(&mut b, sizes);
        let loss = b.g.add(parts.loss_pde, parts.loss_bc);
        let grads = b.g.grad(loss, &weight_ids);
        losses.extend([loss, parts.loss_pde, parts.loss_bc]);
        lane_grads.push(grads);
        lanes.push(LaneBlock {
            lane,
            rows: (r0, r1),
            p: b.p,
            feeds: b.feeds,
            extra_inputs: b.extra_inputs,
        });
        g = b.g;
    }
    let mut outputs = losses;
    for w in 0..weight_ids.len() {
        for grads in &lane_grads {
            outputs.push(grads[w]);
        }
    }
    Ok(BuiltLaneProblem {
        graph: g,
        outputs,
        weight_ids,
        lanes,
        n_lanes,
        coord_dim: dims.coord_dim,
    })
}

/// A plain forward graph `u(p_i, x_j)` for validation / inference.
pub struct ForwardGraph {
    pub graph: Graph,
    /// predicted field (m, n_pts)
    pub u: NodeId,
    pub weight_ids: Vec<NodeId>,
    pub p: NodeId,
    /// per-dimension coordinate columns (n_pts, 1)
    pub coords: Vec<NodeId>,
}

/// Build a strategy-free forward evaluation graph.
pub fn build_forward(m: usize, dims: NetDims, n_pts: usize) -> ForwardGraph {
    let mut b = ProblemBuilder::new(Strategy::Zcs, m, dims);
    let (coords, u) = b.value_block("pts", n_pts);
    ForwardGraph { graph: b.g, u, weight_ids: b.weights.to_vec(), p: b.p, coords }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Program;
    use crate::rng::Pcg64;

    fn sizes() -> BlockSizes {
        BlockSizes { n_in: 6, n_bc: 4 }
    }

    fn feed_everything(built: &BuiltProblem, rng: &mut Pcg64) -> HashMap<NodeId, Tensor> {
        let mut inputs = HashMap::new();
        for &w in &built.weight_ids {
            let shape = built.graph.shape(w).to_vec();
            let n: usize = shape.iter().product();
            inputs.insert(w, Tensor::new(&shape, rng.normals(n)).scale(1.0 / (shape[0] as f64).sqrt()));
        }
        let pshape = built.graph.shape(built.p).to_vec();
        inputs.insert(built.p, Tensor::new(&pshape, rng.normals(pshape.iter().product())));
        for (_, id) in &built.feeds {
            let shape = built.graph.shape(*id).to_vec();
            let n: usize = shape.iter().product();
            inputs.insert(*id, Tensor::new(&shape, rng.uniforms_in(n, 0.1, 0.9)));
        }
        for (id, t) in &built.extra_inputs {
            inputs.insert(*id, t.clone());
        }
        inputs
    }

    #[test]
    fn every_native_problem_builds_and_runs_under_every_strategy() {
        for kind in [
            ProblemKind::Antiderivative,
            ProblemKind::ReactionDiffusion,
            ProblemKind::Burgers,
            ProblemKind::Kirchhoff,
        ] {
            for strategy in Strategy::ALL {
                let built =
                    build_training_problem(kind, strategy, 2, 4, 6, 4, sizes()).unwrap();
                assert_eq!(built.outputs.len(), 7, "{kind:?}/{strategy:?}");
                let prog = Program::compile(&built.graph, &built.outputs);
                let mut rng = Pcg64::seeded(17);
                let inputs = feed_everything(&built, &mut rng);
                let outs = prog.eval_once(&inputs);
                assert_eq!(outs.len(), 7);
                let loss = outs[0].data()[0];
                assert!(loss.is_finite() && loss >= 0.0, "{kind:?}/{strategy:?}: {loss}");
                // loss = loss_pde + loss_bc
                let want = outs[1].data()[0] + outs[2].data()[0];
                assert!((loss - want).abs() <= 1e-12 * (1.0 + loss.abs()));
            }
        }
    }

    #[test]
    fn unsupported_problems_name_the_native_choices() {
        let err = build_training_problem(
            ProblemKind::Stokes,
            Strategy::Zcs,
            2,
            4,
            6,
            4,
            sizes(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("reaction_diffusion"), "{err}");
        assert!(err.contains("antiderivative"), "{err}");
    }

    #[test]
    fn zcs_tape_is_m_invariant_funcloop_grows() {
        let at = |strategy: Strategy, m: usize| {
            build_training_problem(
                ProblemKind::ReactionDiffusion,
                strategy,
                m,
                4,
                6,
                4,
                sizes(),
            )
            .unwrap()
            .graph
            .len()
        };
        assert_eq!(at(Strategy::Zcs, 2), at(Strategy::Zcs, 16));
        assert!(at(Strategy::FuncLoop, 16) > at(Strategy::FuncLoop, 2));
    }

    #[test]
    fn feed_names_follow_the_documented_schema() {
        let built = build_training_problem(
            ProblemKind::Burgers,
            Strategy::Zcs,
            2,
            4,
            6,
            4,
            sizes(),
        )
        .unwrap();
        let names: Vec<&str> = built.feeds.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            [
                "in.x0", "in.x1", "ic.x0", "ic.x1", "ic.u0", "left.x0", "left.x1",
                "right.x0", "right.x1"
            ]
        );
    }

    #[test]
    fn lane_bounds_cover_the_function_rows_exactly() {
        for m in 1..=9 {
            let l = lane_count(m);
            assert_eq!(l, m.min(MAX_LANES));
            let mut next = 0;
            for lane in 0..l {
                let (a, b) = lane_bounds(m, l, lane);
                assert_eq!(a, next, "m={m} lane={lane}");
                assert!(b > a, "m={m}: lane {lane} of {l} is empty");
                next = b;
            }
            assert_eq!(next, m, "m={m}");
        }
    }

    /// Slice function rows [r0, r1) out of an m-rowed tensor.
    fn row_slice(t: &Tensor, r0: usize, r1: usize) -> Tensor {
        let cols: usize = t.shape()[1..].iter().product();
        let mut shape = t.shape().to_vec();
        shape[0] = r1 - r0;
        Tensor::new(&shape, t.data()[r0 * cols..r1 * cols].to_vec())
    }

    #[test]
    fn lane_blocks_reproduce_the_unlaned_losses_and_gradients() {
        // m = 5 -> 4 lanes of sizes 1/1/1/2: feeding each lane its own
        // function rows (and the full point set) must reproduce the
        // unlaned build up to summation association
        let m = 5;
        for kind in [ProblemKind::Antiderivative, ProblemKind::Burgers] {
            for strategy in Strategy::ALL {
                let full = build_training_problem(kind, strategy, m, 4, 6, 4, sizes()).unwrap();
                let n_lanes = lane_count(m);
                let local: Vec<usize> = (0..n_lanes).collect();
                let laned =
                    build_lane_training_problem(kind, strategy, m, &local, 4, 6, 4, sizes())
                        .unwrap();
                assert_eq!(laned.outputs.len(), 3 * n_lanes + 4 * n_lanes);
                assert_eq!(laned.grads_start(), 3 * n_lanes);

                let mut rng = Pcg64::seeded(17);
                let full_inputs = feed_everything(&full, &mut rng);
                let full_outs =
                    Program::compile(&full.graph, &full.outputs).eval_once(&full_inputs);

                let mut inputs = HashMap::new();
                for (i, &w) in laned.weight_ids.iter().enumerate() {
                    inputs.insert(w, full_inputs[&full.weight_ids[i]].clone());
                }
                let by_name: HashMap<&str, &Tensor> = full
                    .feeds
                    .iter()
                    .map(|(name, id)| (name.as_str(), &full_inputs[id]))
                    .collect();
                for blk in &laned.lanes {
                    let (r0, r1) = blk.rows;
                    inputs.insert(blk.p, row_slice(&full_inputs[&full.p], r0, r1));
                    for (name, id) in &blk.feeds {
                        let bare = name.strip_prefix(&format!("l{}.", blk.lane)).unwrap();
                        let src = by_name[bare];
                        let t = if src.shape()[0] == m {
                            row_slice(src, r0, r1) // m-rowed aux feed
                        } else {
                            (*src).clone() // shared point set
                        };
                        inputs.insert(*id, t);
                    }
                    for (id, t) in &blk.extra_inputs {
                        inputs.insert(*id, t.clone());
                    }
                }
                let outs = Program::compile(&laned.graph, &laned.outputs).eval_once(&inputs);

                // losses fold by pure addition, ascending lanes
                for (slot, label) in [(0, "loss"), (1, "pde"), (2, "bc")] {
                    let folded: f64 = (0..n_lanes).map(|l| outs[3 * l + slot].data()[0]).sum();
                    let want = full_outs[slot].data()[0];
                    assert!(
                        (folded - want).abs() <= 1e-12 * (1.0 + want.abs()),
                        "{kind:?}/{strategy:?} {label}: {folded} vs {want}"
                    );
                }
                // per-weight gradients fold the same way
                for w in 0..4 {
                    let want = &full_outs[3 + w];
                    let mut acc = Tensor::zeros(want.shape());
                    for l in 0..n_lanes {
                        let g = &outs[laned.grads_start() + w * n_lanes + l];
                        for (o, x) in acc.data_mut().iter_mut().zip(g.data()) {
                            *o += x;
                        }
                    }
                    for (got, want) in acc.data().iter().zip(want.data()) {
                        assert!(
                            (got - want).abs() <= 1e-10 * (1.0 + want.abs()),
                            "{kind:?}/{strategy:?} grad {w}: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lane_feed_names_carry_the_lane_prefix() {
        let laned = build_lane_training_problem(
            ProblemKind::Burgers,
            Strategy::Zcs,
            5,
            &[1, 3],
            4,
            6,
            4,
            sizes(),
        )
        .unwrap();
        assert_eq!(laned.n_lanes, 4);
        assert_eq!(laned.lanes.len(), 2);
        assert_eq!(laned.lanes[0].lane, 1);
        assert!(laned.lanes[0].feeds.iter().all(|(n, _)| n.starts_with("l1.")));
        assert_eq!(laned.lanes[0].feeds[0].0, "l1.in.x0");
        assert!(laned.lanes[1].feeds.iter().all(|(n, _)| n.starts_with("l3.")));
        // lane 3 of m=5 is the two-row remainder lane
        assert_eq!(laned.lanes[1].rows, (3, 5));
    }

    #[test]
    fn derivative_cache_reuses_chains() {
        // asking for u_x then u_xx must not rebuild the first-order chain
        let dims = NetDims { q: 4, hidden: 6, k: 4, coord_dim: 1 };
        let mut b = ProblemBuilder::new(Strategy::Zcs, 2, dims);
        let mut blk = b.deriv_block("in", 5);
        let d1 = blk.d(&mut b, &[1]);
        let len_after_d1 = b.g.len();
        let d1_again = blk.d(&mut b, &[1]);
        assert_eq!(d1, d1_again);
        assert_eq!(b.g.len(), len_after_d1, "cache hit must not grow the tape");
        let _d2 = blk.d(&mut b, &[2]);
        assert!(b.g.len() > len_after_d1);
    }
}
