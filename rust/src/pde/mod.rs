//! Problem descriptors and the native PDE residual layer.
//!
//! The *physics* of the paper's case studies lives right here, in Rust:
//! [`residual`] builds each problem's PDE residual and boundary/initial
//! losses as native [`crate::autodiff::Graph`] nodes under any of the
//! three AD strategies (FuncLoop / DataVect / ZCS), which the coordinator
//! compiles once and trains end-to-end (`zcs ntrain --problem ...`).  The
//! legacy Python HLO artifacts remain a replayable record of the original
//! XLA lowering, but this module -- not the Python layer -- is the source
//! of truth for the residuals.
//!
//! [`ProblemKind`] itself stays engine-agnostic: which input-function
//! prior to sample, how many output channels, the paper's constants, and
//! (for the artifact path) how each batch array is filled.  Batch array
//! names for artifacts must still match the python `batch_schema` names
//! exactly; the native path instead checks feed names against
//! [`residual::BuiltProblem::feeds`].

pub mod residual;

use crate::sampler::Kernel;

/// The four Table-1 operators, the Fig.-2 scaling operator, and the
/// canonical antiderivative operator the native engine bootstrapped on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    /// learn `u` with `du/dx = f` (the operator-learning "hello world")
    Antiderivative,
    ReactionDiffusion,
    Burgers,
    Kirchhoff,
    Stokes,
    /// eq. (15) with the given max differential order P
    HighOrder(usize),
}

impl ProblemKind {
    /// Every fixed-name problem (excludes the parameterised `highorder_pP`).
    pub const NAMED: [ProblemKind; 5] = [
        ProblemKind::Antiderivative,
        ProblemKind::ReactionDiffusion,
        ProblemKind::Burgers,
        ProblemKind::Kirchhoff,
        ProblemKind::Stokes,
    ];

    /// Parse the manifest / CLI problem name (case-insensitive).
    pub fn from_name(name: &str) -> Option<Self> {
        let name = name.to_ascii_lowercase();
        match name.as_str() {
            "antiderivative" => Some(Self::Antiderivative),
            "reaction_diffusion" => Some(Self::ReactionDiffusion),
            "burgers" => Some(Self::Burgers),
            "kirchhoff" => Some(Self::Kirchhoff),
            "stokes" => Some(Self::Stokes),
            _ => name
                .strip_prefix("highorder_p")
                .and_then(|p| p.parse().ok())
                .map(Self::HighOrder),
        }
    }

    /// Parse with an error message that lists the valid choices.
    pub fn parse(name: &str) -> Result<Self, String> {
        Self::from_name(name).ok_or_else(|| {
            format!(
                "unknown problem {name:?}; valid choices (case-insensitive): \
                 antiderivative, reaction_diffusion, burgers, kirchhoff, stokes, \
                 highorder_pP (e.g. highorder_p3)"
            )
        })
    }

    pub fn name(&self) -> String {
        match self {
            Self::Antiderivative => "antiderivative".into(),
            Self::ReactionDiffusion => "reaction_diffusion".into(),
            Self::Burgers => "burgers".into(),
            Self::Kirchhoff => "kirchhoff".into(),
            Self::Stokes => "stokes".into(),
            Self::HighOrder(p) => format!("highorder_p{p}"),
        }
    }

    /// Output channels (u / {u,v,p}).
    pub fn n_out(&self) -> usize {
        match self {
            Self::Stokes => 3,
            _ => 1,
        }
    }

    /// Max differential order appearing in the PDE (the paper's P).
    pub fn p_order(&self) -> usize {
        match self {
            Self::Antiderivative => 1,
            Self::Kirchhoff => 4,
            Self::HighOrder(p) => *p,
            _ => 2,
        }
    }

    /// The GP prior for the input functions, if the problem uses one
    /// (Kirchhoff samples i.i.d. normal coefficients instead).
    pub fn function_prior(&self) -> Option<Kernel> {
        match self {
            Self::Antiderivative | Self::ReactionDiffusion | Self::HighOrder(_) => {
                Some(Kernel::Rbf { length_scale: 0.2, variance: 1.0 })
            }
            // Burgers initial conditions must be periodic (eq. 17 BC)
            Self::Burgers => Some(Kernel::PeriodicRbf { length_scale: 1.0, variance: 1.0 }),
            // lid velocity; masked by x(1-x) for corner compatibility
            Self::Stokes => Some(Kernel::Rbf { length_scale: 0.2, variance: 1.0 }),
            Self::Kirchhoff => None,
        }
    }

    /// Whether the Stokes corner-compatibility mask applies.
    pub fn lid_mask(&self) -> bool {
        matches!(self, Self::Stokes)
    }

    /// Look up one of the paper's constants by name -- the single source
    /// of truth shared by the residual layer, the batcher's load
    /// synthesis, and validation.
    pub fn constant(&self, name: &str) -> Option<f64> {
        self.constants().into_iter().find(|(n, _)| *n == name).map(|(_, v)| v)
    }

    /// PDE constants, as named in the paper.
    pub fn constants(&self) -> Vec<(&'static str, f64)> {
        match self {
            Self::ReactionDiffusion => vec![("D", 0.01), ("k", 0.01)],
            Self::Burgers => vec![("nu", 0.01)],
            Self::Kirchhoff => vec![("D_flex", 0.01)],
            Self::Stokes => vec![("mu", 0.01)],
            Self::Antiderivative | Self::HighOrder(_) => vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_round_trip() {
        for k in ProblemKind::NAMED {
            assert_eq!(ProblemKind::from_name(&k.name()), Some(k));
        }
        assert_eq!(
            ProblemKind::from_name(&ProblemKind::HighOrder(3).name()),
            Some(ProblemKind::HighOrder(3))
        );
        assert_eq!(ProblemKind::from_name("nope"), None);
        assert_eq!(ProblemKind::from_name("highorder_px"), None);
    }

    #[test]
    fn parsing_is_case_insensitive_and_lists_choices() {
        assert_eq!(ProblemKind::from_name("Burgers"), Some(ProblemKind::Burgers));
        assert_eq!(
            ProblemKind::from_name("REACTION_DIFFUSION"),
            Some(ProblemKind::ReactionDiffusion)
        );
        assert_eq!(ProblemKind::from_name("HIGHORDER_P4"), Some(ProblemKind::HighOrder(4)));
        let err = ProblemKind::parse("bogus").unwrap_err();
        for choice in ["antiderivative", "reaction_diffusion", "burgers", "kirchhoff", "stokes"] {
            assert!(err.contains(choice), "{err}");
        }
    }

    #[test]
    fn constants_lookup_by_name() {
        assert_eq!(ProblemKind::Kirchhoff.constant("D_flex"), Some(0.01));
        assert_eq!(ProblemKind::Burgers.constant("nu"), Some(0.01));
        assert_eq!(ProblemKind::ReactionDiffusion.constant("D"), Some(0.01));
        assert_eq!(ProblemKind::Burgers.constant("bogus"), None);
    }

    #[test]
    fn stokes_is_vector_valued() {
        assert_eq!(ProblemKind::Stokes.n_out(), 3);
        assert_eq!(ProblemKind::Burgers.n_out(), 1);
    }

    #[test]
    fn kirchhoff_is_fourth_order_with_no_gp() {
        assert_eq!(ProblemKind::Kirchhoff.p_order(), 4);
        assert!(ProblemKind::Kirchhoff.function_prior().is_none());
    }

    #[test]
    fn burgers_prior_is_periodic() {
        assert!(matches!(
            ProblemKind::Burgers.function_prior(),
            Some(Kernel::PeriodicRbf { .. })
        ));
    }
}
