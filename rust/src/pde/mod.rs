//! Problem descriptors: the Rust-side mirror of `python/compile/pdes.py`.
//!
//! The Python layer owns the *physics* (residuals are baked into the HLO
//! artifacts); this module owns everything the coordinator must know to
//! *feed* those artifacts: which input-function prior to sample, how each
//! batch array is filled, and which reference solver validates the result.
//! The two sides meet through `artifacts/meta.json` -- batch array names
//! here must match the python `batch_schema` names exactly (checked by the
//! coordinator at batch-build time and by integration tests).

use crate::sampler::Kernel;

/// The four Table-1 operators plus the Fig.-2 scaling operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    ReactionDiffusion,
    Burgers,
    Kirchhoff,
    Stokes,
    /// eq. (15) with the given max differential order P
    HighOrder(usize),
}

impl ProblemKind {
    /// Parse the manifest's problem name.
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "reaction_diffusion" => Some(Self::ReactionDiffusion),
            "burgers" => Some(Self::Burgers),
            "kirchhoff" => Some(Self::Kirchhoff),
            "stokes" => Some(Self::Stokes),
            _ => name
                .strip_prefix("highorder_p")
                .and_then(|p| p.parse().ok())
                .map(Self::HighOrder),
        }
    }

    pub fn name(&self) -> String {
        match self {
            Self::ReactionDiffusion => "reaction_diffusion".into(),
            Self::Burgers => "burgers".into(),
            Self::Kirchhoff => "kirchhoff".into(),
            Self::Stokes => "stokes".into(),
            Self::HighOrder(p) => format!("highorder_p{p}"),
        }
    }

    /// Output channels (u / {u,v,p}).
    pub fn n_out(&self) -> usize {
        match self {
            Self::Stokes => 3,
            _ => 1,
        }
    }

    /// Max differential order appearing in the PDE (the paper's P).
    pub fn p_order(&self) -> usize {
        match self {
            Self::Kirchhoff => 4,
            Self::HighOrder(p) => *p,
            _ => 2,
        }
    }

    /// The GP prior for the input functions, if the problem uses one
    /// (Kirchhoff samples i.i.d. normal coefficients instead).
    pub fn function_prior(&self) -> Option<Kernel> {
        match self {
            Self::ReactionDiffusion | Self::HighOrder(_) => {
                Some(Kernel::Rbf { length_scale: 0.2, variance: 1.0 })
            }
            // Burgers initial conditions must be periodic (eq. 17 BC)
            Self::Burgers => Some(Kernel::PeriodicRbf { length_scale: 1.0, variance: 1.0 }),
            // lid velocity; masked by x(1-x) for corner compatibility
            Self::Stokes => Some(Kernel::Rbf { length_scale: 0.2, variance: 1.0 }),
            Self::Kirchhoff => None,
        }
    }

    /// Whether the Stokes corner-compatibility mask applies.
    pub fn lid_mask(&self) -> bool {
        matches!(self, Self::Stokes)
    }

    /// PDE constants, as named in the paper.
    pub fn constants(&self) -> Vec<(&'static str, f64)> {
        match self {
            Self::ReactionDiffusion => vec![("D", 0.01), ("k", 0.01)],
            Self::Burgers => vec![("nu", 0.01)],
            Self::Kirchhoff => vec![("D_flex", 0.01)],
            Self::Stokes => vec![("mu", 0.01)],
            Self::HighOrder(_) => vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_round_trip() {
        for k in [
            ProblemKind::ReactionDiffusion,
            ProblemKind::Burgers,
            ProblemKind::Kirchhoff,
            ProblemKind::Stokes,
            ProblemKind::HighOrder(3),
        ] {
            assert_eq!(ProblemKind::from_name(&k.name()), Some(k));
        }
        assert_eq!(ProblemKind::from_name("nope"), None);
        assert_eq!(ProblemKind::from_name("highorder_px"), None);
    }

    #[test]
    fn stokes_is_vector_valued() {
        assert_eq!(ProblemKind::Stokes.n_out(), 3);
        assert_eq!(ProblemKind::Burgers.n_out(), 1);
    }

    #[test]
    fn kirchhoff_is_fourth_order_with_no_gp() {
        assert_eq!(ProblemKind::Kirchhoff.p_order(), 4);
        assert!(ProblemKind::Kirchhoff.function_prior().is_none());
    }

    #[test]
    fn burgers_prior_is_periodic() {
        assert!(matches!(
            ProblemKind::Burgers.function_prior(),
            Some(Kernel::PeriodicRbf { .. })
        ));
    }
}
