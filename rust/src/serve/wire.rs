//! Length-prefixed, CRC-framed wire protocol for `zcs serve`.
//!
//! One frame on the wire is
//!
//! ```text
//! magic "ZCSW" (4) | kind (1) | payload_len u32 LE (4) | payload | crc32 u32 LE (4)
//! ```
//!
//! where the CRC (the checkpoint layer's [`crc32`]) covers everything
//! before it -- header *and* payload -- so any torn or bit-flipped
//! frame decodes to a typed [`WireError`] instead of garbage numbers.
//! All multi-byte integers and floats are little-endian; strings are
//! `u16` length + UTF-8.
//!
//! The decoder is total: every truncation prefix and every corrupted
//! byte of a valid frame yields `Err(WireError::..)`, never a panic or
//! a silently wrong [`Frame`].  The serve property tests pin exactly
//! that.

use crate::coordinator::checkpoint::crc32;
use std::io::{Read, Write};

/// Frame magic: "ZCSW" -- ZCS wire.
pub const MAGIC: [u8; 4] = *b"ZCSW";
/// Header bytes before the payload: magic + kind + payload length.
pub const HEADER: usize = 9;
/// Hard cap on payload size; larger length prefixes are malformed.
pub const MAX_PAYLOAD: usize = 1 << 24;

const KIND_REQUEST: u8 = 1;
const KIND_RESPONSE: u8 = 2;
const KIND_SHUTDOWN: u8 = 3;

/// Why a byte buffer is not a frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// fewer bytes than the frame claims; `need` is the total required
    Truncated { what: &'static str, need: usize, have: usize },
    /// first four bytes are not [`MAGIC`]
    BadMagic([u8; 4]),
    /// unknown frame kind byte
    BadKind(u8),
    /// checksum trailer disagrees with the received bytes
    BadCrc { stored: u32, computed: u32 },
    /// structurally invalid payload (bad lengths, counts, UTF-8, ...)
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Truncated { what, need, have } => {
                write!(f, "truncated frame: {what} needs {need} bytes, have {have}")
            }
            Self::BadMagic(m) => write!(f, "bad frame magic {m:?} (want {MAGIC:?})"),
            Self::BadKind(k) => write!(f, "unknown frame kind {k}"),
            Self::BadCrc { stored, computed } => {
                write!(f, "frame CRC mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
            Self::Malformed(what) => write!(f, "malformed frame: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Terminal status of one request, as carried on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Ok,
    /// shed at admission: the bounded queue was full
    Overloaded,
    /// the deadline expired before evaluation finished (or started)
    DeadlineExceeded,
    /// evaluation panicked and the bounded retry also failed
    EvalFailed,
    /// the request itself is invalid (unknown model, bad shapes)
    BadRequest,
}

impl Status {
    fn code(self) -> u8 {
        match self {
            Self::Ok => 0,
            Self::Overloaded => 1,
            Self::DeadlineExceeded => 2,
            Self::EvalFailed => 3,
            Self::BadRequest => 4,
        }
    }

    fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(Self::Ok),
            1 => Some(Self::Overloaded),
            2 => Some(Self::DeadlineExceeded),
            3 => Some(Self::EvalFailed),
            4 => Some(Self::BadRequest),
            _ => None,
        }
    }

    /// Stable name used by `zcs query` output and the CI smoke test.
    pub fn name(self) -> &'static str {
        match self {
            Self::Ok => "ok",
            Self::Overloaded => "overloaded",
            Self::DeadlineExceeded => "deadline-exceeded",
            Self::EvalFailed => "eval-failed",
            Self::BadRequest => "bad-request",
        }
    }
}

/// One operator evaluation request.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalRequest {
    /// registry model id
    pub model: String,
    /// time budget from server receipt; 0 means already expired
    pub deadline_ms: u64,
    /// trunk coordinate dimension of `points`
    pub coord_dim: u8,
    /// branch sensor values (one q-row)
    pub sensors: Vec<f64>,
    /// point-major coordinate block, `n_pts * coord_dim` values
    pub points: Vec<f64>,
}

impl EvalRequest {
    pub fn n_pts(&self) -> usize {
        self.points.len() / self.coord_dim.max(1) as usize
    }
}

/// The server's answer: a status plus values on success.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalResponse {
    pub status: Status,
    /// evaluation attempts beyond the first (0 or 1)
    pub retries: u8,
    /// human-readable detail for non-`Ok` statuses
    pub error: String,
    /// predicted field at the requested points (`Ok` only)
    pub values: Vec<f64>,
}

impl EvalResponse {
    pub fn failure(status: Status, error: impl Into<String>) -> Self {
        Self { status, retries: 0, error: error.into(), values: Vec::new() }
    }
}

/// Everything that can cross the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Request(EvalRequest),
    Response(EvalResponse),
    /// ask the server to drain and exit
    Shutdown,
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    // error strings carry arbitrary text (panic payloads); anything
    // past the u16 length prefix is truncated on a char boundary so
    // encoding never panics on the response path
    let mut end = s.len().min(u16::MAX as usize);
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    put_u16(out, end as u16);
    out.extend_from_slice(&s.as_bytes()[..end]);
}

/// Encode one frame, CRC trailer included.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let (kind, payload) = match frame {
        Frame::Request(req) => {
            let mut p = Vec::new();
            put_str(&mut p, &req.model);
            put_u64(&mut p, req.deadline_ms);
            p.push(req.coord_dim);
            put_f64s(&mut p, &req.sensors);
            put_f64s(&mut p, &req.points);
            (KIND_REQUEST, p)
        }
        Frame::Response(resp) => {
            let mut p = Vec::new();
            p.push(resp.status.code());
            p.push(resp.retries);
            put_str(&mut p, &resp.error);
            put_f64s(&mut p, &resp.values);
            (KIND_RESPONSE, p)
        }
        Frame::Shutdown => (KIND_SHUTDOWN, Vec::new()),
    };
    assert!(payload.len() <= MAX_PAYLOAD, "frame payload exceeds the wire cap");
    let mut out = Vec::with_capacity(HEADER + payload.len() + 4);
    out.extend_from_slice(&MAGIC);
    out.push(kind);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    let crc = crc32(&out);
    put_u32(&mut out, crc);
    out
}

/// Bounds-checked payload reader with typed errors.
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], WireError> {
        let have = self.buf.len() - self.pos;
        if have < n {
            return Err(WireError::Truncated { what, need: n, have });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64s(&mut self, what: &'static str) -> Result<Vec<f64>, WireError> {
        let n = self.u32(what)? as usize;
        if n > (self.buf.len() - self.pos) / 8 {
            return Err(WireError::Malformed("float count exceeds payload"));
        }
        let bytes = self.take(n * 8, what)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn string(&mut self, what: &'static str) -> Result<String, WireError> {
        let n = self.u16(what)? as usize;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::Malformed("string is not UTF-8"))
    }

    fn done(self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Malformed("trailing bytes after payload"));
        }
        Ok(())
    }
}

fn decode_request(payload: &[u8]) -> Result<EvalRequest, WireError> {
    let mut rd = Rd::new(payload);
    let model = rd.string("request model id")?;
    let deadline_ms = rd.u64("request deadline")?;
    let coord_dim = rd.u8("request coord_dim")?;
    let sensors = rd.f64s("request sensors")?;
    let points = rd.f64s("request points")?;
    rd.done()?;
    if coord_dim == 0 {
        return Err(WireError::Malformed("coord_dim must be at least 1"));
    }
    if points.len() % coord_dim as usize != 0 {
        return Err(WireError::Malformed("points not a multiple of coord_dim"));
    }
    Ok(EvalRequest { model, deadline_ms, coord_dim, sensors, points })
}

fn decode_response(payload: &[u8]) -> Result<EvalResponse, WireError> {
    let mut rd = Rd::new(payload);
    let code = rd.u8("response status")?;
    let status = Status::from_code(code).ok_or(WireError::Malformed("unknown status code"))?;
    let retries = rd.u8("response retries")?;
    let error = rd.string("response error")?;
    let values = rd.f64s("response values")?;
    rd.done()?;
    Ok(EvalResponse { status, retries, error, values })
}

/// Decode one frame from the head of `buf`.  Returns the frame and the
/// number of bytes consumed (extra trailing bytes are the next frame's
/// business).
pub fn decode(buf: &[u8]) -> Result<(Frame, usize), WireError> {
    if buf.len() < HEADER {
        return Err(WireError::Truncated { what: "frame header", need: HEADER, have: buf.len() });
    }
    let magic: [u8; 4] = buf[..4].try_into().unwrap();
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let kind = buf[4];
    let len = u32::from_le_bytes(buf[5..9].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(WireError::Malformed("payload length exceeds the wire cap"));
    }
    let total = HEADER + len + 4;
    if buf.len() < total {
        return Err(WireError::Truncated { what: "frame body", need: total, have: buf.len() });
    }
    let stored = u32::from_le_bytes(buf[total - 4..total].try_into().unwrap());
    let computed = crc32(&buf[..total - 4]);
    if stored != computed {
        return Err(WireError::BadCrc { stored, computed });
    }
    let payload = &buf[HEADER..total - 4];
    let frame = match kind {
        KIND_REQUEST => Frame::Request(decode_request(payload)?),
        KIND_RESPONSE => Frame::Response(decode_response(payload)?),
        KIND_SHUTDOWN => {
            if !payload.is_empty() {
                return Err(WireError::Malformed("shutdown frame carries no payload"));
            }
            Frame::Shutdown
        }
        other => return Err(WireError::BadKind(other)),
    };
    Ok((frame, total))
}

/// Read exactly one frame from a stream.  The outer `Err` is transport
/// (EOF, reset, timeout); the inner `Err` is a protocol violation the
/// caller should answer with `BadRequest` before hanging up.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Result<Frame, WireError>> {
    let mut header = [0u8; HEADER];
    r.read_exact(&mut header)?;
    let magic: [u8; 4] = header[..4].try_into().unwrap();
    if magic != MAGIC {
        return Ok(Err(WireError::BadMagic(magic)));
    }
    let len = u32::from_le_bytes(header[5..9].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Ok(Err(WireError::Malformed("payload length exceeds the wire cap")));
    }
    let mut rest = vec![0u8; len + 4];
    r.read_exact(&mut rest)?;
    let mut whole = header.to_vec();
    whole.extend_from_slice(&rest);
    Ok(decode(&whole).map(|(frame, _)| frame))
}

/// Write one frame to a stream.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&encode(frame))
}
