//! Hardened operator serving: `zcs serve`.
//!
//! A pure-std TCP server that evaluates trained operators through
//! inference-only [`Program`](crate::autodiff::Program)s
//! ([`Program::compile_inference`](crate::autodiff::Program::compile_inference))
//! resident in warm executors.  The design is degradation-first --
//! every way a request can fail maps to one typed
//! [`Status`](wire::Status) the client can act on:
//!
//! * **load shedding** -- admission goes through a *bounded* queue;
//!   when it is full the request is refused with `Overloaded`
//!   immediately instead of queueing without bound;
//! * **deadlines** -- every request carries a time budget.  A request
//!   that expires in the queue is answered `DeadlineExceeded` and
//!   *never reaches an executor*; one that expires during evaluation
//!   is answered `DeadlineExceeded` instead of a stale `Ok`;
//! * **panic isolation + bounded retry** -- evaluation runs under
//!   `catch_unwind` on worker threads (on top of the executor pool's
//!   own panic draining, [`crate::util::pool`]); a panicked batch is
//!   retried once on a freshly compiled resident executor, then fails
//!   typed with `EvalFailed`;
//! * **graceful drain** -- shutdown (a [`wire::Frame::Shutdown`]
//!   frame, [`ServerHandle::shutdown`], or the `--shutdown-file`
//!   flag file appearing) stops accepting, finishes everything
//!   already admitted, answers it, and only then exits.
//!
//! Requests for the same model with the bit-identical coordinate
//! block are **coalesced** by a dispatcher into one multi-sample
//! batched program execution (up to `max_batch`, waiting at most
//! `linger`), so concurrent query traffic rides the same batched
//! forward pass the trainer uses.
//!
//! Fault injection: `ZCS_FAULT=eval-panic:K` panics the K-th
//! evaluation attempt, `slow:K` stalls it, `conn-drop:K` drops the
//! K-th accepted connection ([`crate::util::env::parse_fault`]).

pub mod wire;

use crate::coordinator::registry::{Model, Registry, ResidentModel};
use crate::util::env::{FaultCell, FaultKind};
use anyhow::{anyhow, Context, Result};
use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};
use self::wire::{EvalRequest, EvalResponse, Frame, Status};

/// How many resident executors one worker keeps warm before evicting.
const RESIDENT_CACHE_CAP: usize = 8;

/// Server knobs.  Defaults are sized for tests; `zcs serve` overrides
/// from the command line.
#[derive(Clone)]
pub struct ServeConfig {
    /// bind address; use port 0 to let the OS pick (tests)
    pub addr: String,
    /// bounded admission queue capacity; overflow is shed typed
    pub queue_cap: usize,
    /// max requests coalesced into one batched program execution
    pub max_batch: usize,
    /// how long the dispatcher waits for compatible requests
    pub linger: Duration,
    /// evaluation worker threads (each owns its resident executors)
    pub workers: usize,
    /// executor pool threads per worker
    pub threads: usize,
    /// touch this file to request a graceful drain (SIGTERM stand-in)
    pub shutdown_file: Option<String>,
    /// hard cap on concurrently open connections; one over the cap is
    /// answered `Overloaded` and closed without spawning a handler
    pub max_conns: usize,
    /// reclaim a connection idle (no frame) for this long; `None`
    /// leaves idle connections open until drain
    pub read_timeout: Option<Duration>,
    /// per-request cap on evaluation points; larger requests are
    /// answered `BadRequest` before any executor is compiled
    pub max_points: usize,
    /// injected faults; `zcs serve` wires `ZCS_FAULT` through here
    pub fault: Option<Arc<FaultCell>>,
    /// how long an injected `slow:K` fault stalls an evaluation
    pub slow_stall: Duration,
    /// request stall watchdog: when set, a request whose answer does not
    /// arrive within its own deadline *plus* this grace gets a typed
    /// `EvalFailed` instead of blocking its connection forever.  Armed
    /// by default under `ZCS_SANITIZE=full` with `ZCS_STALL_MS`
    pub stall: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            queue_cap: 64,
            max_batch: 8,
            linger: Duration::from_millis(2),
            workers: 2,
            threads: 1,
            shutdown_file: None,
            max_conns: 256,
            read_timeout: Some(Duration::from_secs(30)),
            max_points: 1 << 16,
            fault: None,
            slow_stall: Duration::from_millis(300),
            stall: crate::util::env::env_sanitize()
                .dynamic()
                .then(|| Duration::from_millis(crate::util::env::env_stall_ms())),
        }
    }
}

/// Lifetime totals, snapshotted when the server drains.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServeReport {
    /// requests admitted to the queue
    pub admitted: u64,
    /// requests answered `Ok`
    pub served: u64,
    /// requests shed at admission (`Overloaded`)
    pub shed: u64,
    /// requests answered `DeadlineExceeded`
    pub deadline_missed: u64,
    /// requests answered `BadRequest` (including wire errors)
    pub bad_requests: u64,
    /// batched program evaluation attempts started
    pub evals: u64,
    /// evaluation attempts that were retries after a panic
    pub retries: u64,
    /// requests answered `EvalFailed`
    pub failed: u64,
    /// connections accepted
    pub conns: u64,
    /// connections dropped by the `conn-drop` fault
    pub conns_dropped: u64,
    /// connections refused `Overloaded` at the `max_conns` cap
    pub conns_rejected: u64,
}

#[derive(Default)]
struct Counters {
    admitted: AtomicU64,
    served: AtomicU64,
    shed: AtomicU64,
    deadline_missed: AtomicU64,
    bad_requests: AtomicU64,
    evals: AtomicU64,
    retries: AtomicU64,
    failed: AtomicU64,
    conns: AtomicU64,
    conns_dropped: AtomicU64,
    conns_rejected: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> ServeReport {
        let get = |c: &AtomicU64| c.load(Ordering::Acquire);
        ServeReport {
            admitted: get(&self.admitted),
            served: get(&self.served),
            shed: get(&self.shed),
            deadline_missed: get(&self.deadline_missed),
            bad_requests: get(&self.bad_requests),
            evals: get(&self.evals),
            retries: get(&self.retries),
            failed: get(&self.failed),
            conns: get(&self.conns),
            conns_dropped: get(&self.conns_dropped),
            conns_rejected: get(&self.conns_rejected),
        }
    }
}

/// One admitted request on its way to an executor.
struct Job {
    model: Arc<Model>,
    sensors: Vec<f64>,
    points: Vec<f64>,
    deadline: Instant,
    resp: mpsc::Sender<EvalResponse>,
}

impl Job {
    /// Coalescing rule: same loaded model (pointer identity, so a hot
    /// reload splits batches) and the bit-identical coordinate block.
    fn compatible(&self, other: &Job) -> bool {
        Arc::ptr_eq(&self.model, &other.model)
            && self.points.len() == other.points.len()
            && self.points.iter().zip(&other.points).all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

/// A bounded MPMC queue with close semantics.
struct Queue<T> {
    inner: Mutex<QueueInner<T>>,
    cv: Condvar,
    cap: usize,
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Queue<T> {
    fn new(cap: usize) -> Self {
        assert!(cap >= 1, "a queue needs capacity");
        let inner = Mutex::new(QueueInner { items: VecDeque::new(), closed: false });
        Self { inner, cv: Condvar::new(), cap }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueInner<T>> {
        self.inner.lock().expect("serve queue lock")
    }

    /// Non-blocking admission: the item comes back on overflow so the
    /// caller can answer `Overloaded`.
    fn try_push(&self, item: T) -> std::result::Result<(), T> {
        let mut g = self.lock();
        if g.closed || g.items.len() >= self.cap {
            return Err(item);
        }
        g.items.push_back(item);
        drop(g);
        self.cv.notify_all();
        Ok(())
    }

    /// Blocking push (dispatcher -> workers backpressure).  Fails only
    /// after close.
    fn push_wait(&self, item: T) -> std::result::Result<(), T> {
        let mut g = self.lock();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.cap {
                g.items.push_back(item);
                drop(g);
                self.cv.notify_all();
                return Ok(());
            }
            g = self.cv.wait(g).expect("serve queue lock");
        }
    }

    /// Blocking pop; `None` once the queue is closed *and* empty.
    fn pop_wait(&self) -> Option<T> {
        let mut g = self.lock();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.cv.notify_all();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).expect("serve queue lock");
        }
    }

    /// Pop the first item matching `pred`, waiting until `until` for
    /// one to arrive.  `None` on timeout or close-and-no-match.
    fn pop_matching_until(&self, pred: impl Fn(&T) -> bool, until: Instant) -> Option<T> {
        let mut g = self.lock();
        loop {
            if let Some(i) = g.items.iter().position(&pred) {
                let item = g.items.remove(i).expect("position just found");
                drop(g);
                self.cv.notify_all();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            g = self.cv.wait_timeout(g, until - now).expect("serve queue lock").0;
        }
    }

    fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }
}

struct ServerCtx {
    registry: Arc<Registry>,
    admission: Queue<Job>,
    work: Queue<Vec<Job>>,
    counters: Counters,
    shutdown: Arc<AtomicBool>,
    /// admitted requests whose response has not been written yet
    in_flight: AtomicU64,
    /// live connections by id: each handler removes its own entry in
    /// its epilogue, so the map's length is the live-connection count
    /// and a dup'd stream never outlives its handler.  Drain uses the
    /// survivors to unblock idle readers.
    conns: Mutex<HashMap<u64, TcpStream>>,
    read_timeout: Option<Duration>,
    max_points: usize,
    fault: Option<Arc<FaultCell>>,
    threads: usize,
    slow_stall: Duration,
    /// request stall watchdog grace (see [`ServeConfig::stall`])
    stall: Option<Duration>,
}

/// A running server.  Drop the handle without `join` and the server
/// keeps running until told to shut down.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    join: thread::JoinHandle<ServeReport>,
}

/// A cloneable token that can request a drain from any thread (the
/// `zcs serve` stdin watcher uses one).
#[derive(Clone)]
pub struct ShutdownTrigger(Arc<AtomicBool>);

impl ShutdownTrigger {
    pub fn fire(&self) {
        self.0.store(true, Ordering::Release);
    }
}

impl ServerHandle {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request a graceful drain; returns immediately.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
    }

    /// A detached drain trigger usable from other threads.
    pub fn trigger(&self) -> ShutdownTrigger {
        ShutdownTrigger(Arc::clone(&self.shutdown))
    }

    /// Wait for the drain to finish and collect the totals.
    pub fn join(self) -> ServeReport {
        self.join.join().expect("server thread panicked")
    }
}

/// Bind and start serving `registry` per `cfg`.
pub fn serve(registry: Arc<Registry>, cfg: ServeConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding serve listener on {}", cfg.addr))?;
    let addr = listener.local_addr().context("resolving serve listener address")?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let ctx = Arc::new(ServerCtx {
        registry,
        admission: Queue::new(cfg.queue_cap),
        work: Queue::new(cfg.workers.max(1)),
        counters: Counters::default(),
        shutdown: Arc::clone(&shutdown),
        in_flight: AtomicU64::new(0),
        conns: Mutex::new(HashMap::new()),
        read_timeout: cfg.read_timeout.filter(|d| !d.is_zero()),
        max_points: cfg.max_points.max(1),
        fault: cfg.fault.clone(),
        threads: cfg.threads,
        slow_stall: cfg.slow_stall,
        stall: cfg.stall.filter(|d| !d.is_zero()),
    });
    let join = thread::Builder::new()
        .name("zcs-serve".to_string())
        .spawn(move || run_server(ctx, listener, cfg))
        .context("spawning serve thread")?;
    Ok(ServerHandle { addr, shutdown, join })
}

fn run_server(ctx: Arc<ServerCtx>, listener: TcpListener, cfg: ServeConfig) -> ServeReport {
    let dispatcher = {
        let ctx = Arc::clone(&ctx);
        let max_batch = cfg.max_batch.max(1);
        let linger = cfg.linger;
        thread::spawn(move || dispatch_loop(&ctx, max_batch, linger))
    };
    let workers: Vec<_> = (0..cfg.workers.max(1))
        .map(|_| {
            let ctx = Arc::clone(&ctx);
            thread::spawn(move || worker_loop(&ctx))
        })
        .collect();

    listener.set_nonblocking(true).expect("nonblocking serve listener");
    let max_conns = cfg.max_conns.max(1);
    let mut conn_threads: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut accepted: u64 = 0;
    while !ctx.shutdown.load(Ordering::Acquire) {
        if let Some(f) = &cfg.shutdown_file {
            if Path::new(f).exists() {
                ctx.shutdown.store(true, Ordering::Release);
                break;
            }
        }
        // reap handlers whose connection has closed, so a long-running
        // server holds threads (and stream dups) only for live clients
        let mut i = 0;
        while i < conn_threads.len() {
            if conn_threads[i].is_finished() {
                let _ = conn_threads.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                accepted += 1;
                ctx.counters.conns.fetch_add(1, Ordering::AcqRel);
                let dropped = ctx
                    .fault
                    .as_ref()
                    .is_some_and(|f| f.should_fire(FaultKind::ConnDrop, accepted));
                if dropped {
                    ctx.counters.conns_dropped.fetch_add(1, Ordering::AcqRel);
                    drop(stream);
                    continue;
                }
                if ctx.conns.lock().expect("conn registry").len() >= max_conns {
                    ctx.counters.conns_rejected.fetch_add(1, Ordering::AcqRel);
                    let msg = format!("connection limit ({max_conns}) reached");
                    let resp = EvalResponse::failure(Status::Overloaded, msg);
                    let _ = wire::write_frame(&mut stream, &Frame::Response(resp));
                    continue;
                }
                // the dup unblocks this connection's read at drain time;
                // if we cannot register it we cannot drain it -- refuse
                let Ok(clone) = stream.try_clone() else {
                    drop(stream);
                    continue;
                };
                let conn_id = accepted;
                ctx.conns.lock().expect("conn registry").insert(conn_id, clone);
                let ctx = Arc::clone(&ctx);
                conn_threads.push(thread::spawn(move || {
                    let _ = catch_unwind(AssertUnwindSafe(|| conn_loop(stream, &ctx)));
                    ctx.conns.lock().expect("conn registry").remove(&conn_id);
                }));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }

    // Drain: stop accepting, let everything already admitted finish
    // and get answered, then unblock idle connections and exit.
    drop(listener);
    ctx.admission.close();
    dispatcher.join().expect("dispatcher thread panicked");
    ctx.work.close();
    for w in workers {
        w.join().expect("worker thread panicked");
    }
    let drain_start = Instant::now();
    while ctx.in_flight.load(Ordering::Acquire) > 0
        && drain_start.elapsed() < Duration::from_secs(10)
    {
        thread::sleep(Duration::from_millis(2));
    }
    for s in ctx.conns.lock().expect("conn registry").values() {
        let _ = s.shutdown(std::net::Shutdown::Both);
    }
    for c in conn_threads {
        let _ = c.join();
    }
    ctx.counters.snapshot()
}

fn conn_loop(mut stream: TcpStream, ctx: &ServerCtx) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(ctx.read_timeout);
    loop {
        let frame = match wire::read_frame(&mut stream) {
            Ok(Ok(frame)) => frame,
            Ok(Err(werr)) => {
                // framing is gone on this connection: answer typed,
                // then hang up rather than resynchronise garbage
                ctx.counters.bad_requests.fetch_add(1, Ordering::AcqRel);
                let resp = EvalResponse::failure(Status::BadRequest, format!("wire error: {werr}"));
                let _ = wire::write_frame(&mut stream, &Frame::Response(resp));
                return;
            }
            Err(_) => return, // EOF, reset, or idle past the read timeout
        };
        match frame {
            Frame::Shutdown => {
                ctx.shutdown.store(true, Ordering::Release);
                let ack = EvalResponse {
                    status: Status::Ok,
                    retries: 0,
                    error: "draining".to_string(),
                    values: Vec::new(),
                };
                let _ = wire::write_frame(&mut stream, &Frame::Response(ack));
                return;
            }
            Frame::Response(_) => {
                ctx.counters.bad_requests.fetch_add(1, Ordering::AcqRel);
                return;
            }
            Frame::Request(req) => {
                let (resp, admitted) = handle_request(ctx, req);
                let write_ok = wire::write_frame(&mut stream, &Frame::Response(resp)).is_ok();
                if admitted {
                    ctx.in_flight.fetch_sub(1, Ordering::AcqRel);
                }
                if !write_ok || ctx.shutdown.load(Ordering::Acquire) {
                    return;
                }
            }
        }
    }
}

/// Validate, admit, and wait for the answer.  The bool says whether
/// the request was admitted (and thus holds an `in_flight` slot until
/// the caller has written the response).
fn handle_request(ctx: &ServerCtx, req: EvalRequest) -> (EvalResponse, bool) {
    let bad = |msg: String| {
        ctx.counters.bad_requests.fetch_add(1, Ordering::AcqRel);
        (EvalResponse::failure(Status::BadRequest, msg), false)
    };
    let model = match ctx.registry.get(&req.model) {
        Ok(model) => model,
        Err(e) => return bad(e.to_string()),
    };
    if req.coord_dim as usize != model.dims.coord_dim {
        return bad(format!(
            "model {:?} wants coord_dim {}, request has {}",
            model.id, model.dims.coord_dim, req.coord_dim
        ));
    }
    if req.sensors.len() != model.dims.q {
        return bad(format!(
            "model {:?} wants {} sensor values, request has {}",
            model.id,
            model.dims.q,
            req.sensors.len()
        ));
    }
    if req.points.is_empty() {
        return bad("request has no evaluation points".to_string());
    }
    let n_pts = req.points.len() / model.dims.coord_dim;
    if n_pts > ctx.max_points {
        // a fresh (batch, n_pts) shape costs a program compile on a
        // worker; unbounded client-picked shapes would be
        // compile-amplification, so cap them at admission
        return bad(format!("request has {n_pts} points, the server caps at {}", ctx.max_points));
    }
    let deadline = Instant::now() + Duration::from_millis(req.deadline_ms);
    let (tx, rx) = mpsc::channel();
    let job = Job { model, sensors: req.sensors, points: req.points, deadline, resp: tx };
    // claim the in-flight slot before admission so the drain loop can
    // never observe an admitted-but-uncounted request
    ctx.in_flight.fetch_add(1, Ordering::AcqRel);
    if ctx.admission.try_push(job).is_err() {
        ctx.in_flight.fetch_sub(1, Ordering::AcqRel);
        ctx.counters.shed.fetch_add(1, Ordering::AcqRel);
        let msg = "admission queue full, request shed".to_string();
        return (EvalResponse::failure(Status::Overloaded, msg), false);
    }
    ctx.counters.admitted.fetch_add(1, Ordering::AcqRel);
    let reply = match ctx.stall {
        None => rx.recv().ok(),
        Some(grace) => {
            // stall watchdog: if neither the dispatcher nor a worker
            // answers within the request's own deadline plus this grace,
            // something in the pipeline is wedged -- answer typed instead
            // of blocking this connection forever
            let budget = Duration::from_millis(req.deadline_ms).saturating_add(grace);
            match rx.recv_timeout(budget) {
                Ok(resp) => Some(resp),
                Err(mpsc::RecvTimeoutError::Disconnected) => None,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    ctx.counters.failed.fetch_add(1, Ordering::AcqRel);
                    let msg = format!(
                        "server stalled: no response within the {}ms deadline plus \
                         {grace:?} watchdog grace",
                        req.deadline_ms
                    );
                    return (EvalResponse::failure(Status::EvalFailed, msg), true);
                }
            }
        }
    };
    match reply {
        Some(resp) => (resp, true),
        None => {
            let msg = "request dropped during shutdown".to_string();
            (EvalResponse::failure(Status::EvalFailed, msg), true)
        }
    }
}

fn respond_deadline(ctx: &ServerCtx, job: &Job, where_: &str) {
    ctx.counters.deadline_missed.fetch_add(1, Ordering::AcqRel);
    let msg = format!("deadline expired {where_}");
    let _ = job.resp.send(EvalResponse::failure(Status::DeadlineExceeded, msg));
}

/// Pull admitted jobs, expire the dead ones *before* they reach any
/// executor, coalesce compatible ones, hand batches to workers.
fn dispatch_loop(ctx: &ServerCtx, max_batch: usize, linger: Duration) {
    while let Some(job) = ctx.admission.pop_wait() {
        if job.deadline <= Instant::now() {
            respond_deadline(ctx, &job, "in the admission queue");
            continue;
        }
        let mut batch = vec![job];
        let linger_end = Instant::now() + linger;
        while batch.len() < max_batch {
            let lead = &batch[0];
            match ctx.admission.pop_matching_until(|j| lead.compatible(j), linger_end) {
                Some(j) => batch.push(j),
                None => break,
            }
        }
        if ctx.work.push_wait(batch).is_err() {
            // only after a hard close; the drain path never hits this
            return;
        }
    }
}

fn panic_text(e: Box<dyn Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        return (*s).to_string();
    }
    if let Some(s) = e.downcast_ref::<String>() {
        return s.clone();
    }
    "opaque panic payload".to_string()
}

/// Evaluate batches on panic-isolated resident executors.
fn worker_loop(ctx: &ServerCtx) {
    // (model id, generation, batch, n_pts) -> (last-use tick, warm
    // resident executor); the tick makes eviction LRU so one odd-shaped
    // request cannot flush every other warm shape
    let mut cache: HashMap<(String, u64, usize, usize), (u64, ResidentModel)> = HashMap::new();
    let mut tick: u64 = 0;
    while let Some(batch) = ctx.work.pop_wait() {
        let now = Instant::now();
        let (live, expired): (Vec<Job>, Vec<Job>) =
            batch.into_iter().partition(|j| j.deadline > now);
        for job in &expired {
            respond_deadline(ctx, job, "waiting for an executor");
        }
        let Some(lead) = live.first() else { continue };
        let model = Arc::clone(&lead.model);
        let m = live.len();
        let n_pts = lead.points.len() / model.dims.coord_dim;
        let key = (model.id.clone(), model.generation, m, n_pts);
        let sensors: Vec<&[f64]> = live.iter().map(|j| j.sensors.as_slice()).collect();

        tick += 1;
        let mut retried = false;
        let outcome = loop {
            if !cache.contains_key(&key) {
                // retire executors compiled against stale generations
                // of this model, and keep the cache bounded by evicting
                // the least recently used shape only
                cache.retain(|k, _| k.0 != model.id || k.1 == model.generation);
                while cache.len() >= RESIDENT_CACHE_CAP {
                    let lru = cache
                        .iter()
                        .min_by_key(|(_, (used, _))| *used)
                        .map(|(k, _)| k.clone())
                        .expect("non-empty cache");
                    cache.remove(&lru);
                }
                cache.insert(key.clone(), (tick, model.resident(m, n_pts, ctx.threads)));
            }
            let entry = cache.get_mut(&key).expect("just inserted");
            entry.0 = tick;
            let resident = &mut entry.1;
            let attempt = ctx.counters.evals.fetch_add(1, Ordering::AcqRel) + 1;
            if retried {
                ctx.counters.retries.fetch_add(1, Ordering::AcqRel);
            }
            if let Some(f) = &ctx.fault {
                if f.should_fire(FaultKind::Slow, attempt) {
                    thread::sleep(ctx.slow_stall);
                }
            }
            let inject =
                ctx.fault.as_ref().is_some_and(|f| f.should_fire(FaultKind::EvalPanic, attempt));
            let result = catch_unwind(AssertUnwindSafe(|| {
                if inject {
                    panic!("injected eval panic (attempt {attempt})");
                }
                resident.eval(&sensors, &lead.points)
            }));
            match result {
                Ok(rows) => break Ok(rows),
                Err(payload) => {
                    // don't trust an executor a panic unwound through:
                    // recompile fresh for the one bounded retry
                    cache.remove(&key);
                    if retried {
                        break Err(panic_text(payload));
                    }
                    retried = true;
                }
            }
        };
        let retries = u8::from(retried);
        match outcome {
            Ok(rows) => {
                let done = Instant::now();
                for (job, row) in live.iter().zip(rows) {
                    if job.deadline <= done {
                        respond_deadline(ctx, job, "during evaluation");
                        continue;
                    }
                    ctx.counters.served.fetch_add(1, Ordering::AcqRel);
                    let resp = EvalResponse {
                        status: Status::Ok,
                        retries,
                        error: String::new(),
                        values: row,
                    };
                    let _ = job.resp.send(resp);
                }
            }
            Err(text) => {
                for job in &live {
                    ctx.counters.failed.fetch_add(1, Ordering::AcqRel);
                    let msg = format!("evaluation panicked twice, giving up: {text}");
                    let _ = job.resp.send(EvalResponse::failure(Status::EvalFailed, msg));
                }
            }
        }
    }
}

/// A blocking client for one serve connection.  Used by `zcs query`,
/// the integration tests, and the serve benchmark.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Self { stream })
    }

    fn roundtrip(&mut self, frame: &Frame) -> Result<EvalResponse> {
        wire::write_frame(&mut self.stream, frame).context("writing request frame")?;
        let frame = wire::read_frame(&mut self.stream)
            .context("reading response frame")?
            .map_err(|werr| anyhow!("protocol error in response: {werr}"))?;
        match frame {
            Frame::Response(resp) => Ok(resp),
            other => Err(anyhow!("expected a response frame, got {other:?}")),
        }
    }

    /// Evaluate one request; the typed outcome is in the response's
    /// [`Status`], transport failures in the `Err`.
    pub fn eval(&mut self, req: &EvalRequest) -> Result<EvalResponse> {
        self.roundtrip(&Frame::Request(req.clone()))
    }

    /// Ask the server to drain; the ack confirms it heard us.
    pub fn shutdown(&mut self) -> Result<EvalResponse> {
        self.roundtrip(&Frame::Shutdown)
    }
}
