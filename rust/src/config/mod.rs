//! Configuration: a minimal-TOML parser + the typed run configuration.
//!
//! Training runs are driven either from CLI flags or from a config file in
//! a TOML subset (tables, `key = value` with strings / numbers / booleans /
//! flat arrays, `#` comments) -- enough for `configs/*.toml` without an
//! external dependency.

mod toml;

pub use toml::{parse_toml, TomlError, TomlValue};

use anyhow::{bail, Result};

/// One training run, fully specified.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub problem: String,
    pub strategy: String,
    pub scale: String,
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    /// functions in the pre-generated bank
    pub bank_size: usize,
    /// fine-grid resolution of the GP bank
    pub bank_grid: usize,
    /// validate against the reference solver after training
    pub validate: bool,
    /// how many bank functions to validate on
    pub validate_functions: usize,
    pub artifact_dir: String,
    pub checkpoint: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            problem: "reaction_diffusion".into(),
            strategy: "zcs".into(),
            scale: "bench".into(),
            steps: 200,
            seed: 20230923,
            log_every: 20,
            bank_size: 1000,
            bank_grid: 256,
            validate: false,
            validate_functions: 8,
            artifact_dir: "artifacts".into(),
            checkpoint: None,
        }
    }
}

impl RunConfig {
    /// Load from a TOML file: top-level keys plus an optional `[train]` table.
    pub fn from_toml_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let root = parse_toml(&text)?;
        let mut cfg = Self::default();
        let mut apply = |tv: &std::collections::BTreeMap<String, TomlValue>| -> Result<()> {
            for (k, v) in tv {
                match (k.as_str(), v) {
                    ("problem", TomlValue::Str(s)) => cfg.problem = s.clone(),
                    ("strategy", TomlValue::Str(s)) => cfg.strategy = s.clone(),
                    ("scale", TomlValue::Str(s)) => cfg.scale = s.clone(),
                    ("steps", TomlValue::Int(i)) => cfg.steps = *i as usize,
                    ("seed", TomlValue::Int(i)) => cfg.seed = *i as u64,
                    ("log_every", TomlValue::Int(i)) => cfg.log_every = *i as usize,
                    ("bank_size", TomlValue::Int(i)) => cfg.bank_size = *i as usize,
                    ("bank_grid", TomlValue::Int(i)) => cfg.bank_grid = *i as usize,
                    ("validate", TomlValue::Bool(b)) => cfg.validate = *b,
                    ("validate_functions", TomlValue::Int(i)) => {
                        cfg.validate_functions = *i as usize
                    }
                    ("artifact_dir", TomlValue::Str(s)) => cfg.artifact_dir = s.clone(),
                    ("checkpoint", TomlValue::Str(s)) => cfg.checkpoint = Some(s.clone()),
                    (key, val) => bail!("unknown/ill-typed config key {key} = {val:?}"),
                }
            }
            Ok(())
        };
        match &root {
            TomlValue::Table(t) => {
                // allow either flat keys or a [train] table
                let mut flat = std::collections::BTreeMap::new();
                for (k, v) in t {
                    if let TomlValue::Table(sub) = v {
                        if k == "train" {
                            apply(sub)?;
                        }
                    } else {
                        flat.insert(k.clone(), v.clone());
                    }
                }
                apply(&flat)?;
            }
            _ => bail!("config root must be a table"),
        }
        Ok(cfg)
    }

    /// The manifest artifact names this run uses.
    pub fn train_artifact(&self) -> String {
        format!("{}__{}__{}.train", self.problem, self.strategy, self.scale)
    }

    pub fn loss_artifact(&self) -> String {
        format!("{}__{}__{}.loss", self.problem, self.strategy, self.scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_round_trip_names() {
        let c = RunConfig::default();
        assert_eq!(c.train_artifact(), "reaction_diffusion__zcs__bench.train");
        assert_eq!(c.loss_artifact(), "reaction_diffusion__zcs__bench.loss");
    }

    #[test]
    fn from_toml_file_applies_keys() {
        let dir = std::env::temp_dir().join("zcs_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.toml");
        std::fs::write(
            &path,
            "# a run\nproblem = \"stokes\"\nsteps = 42\nvalidate = true\n\n[train]\nseed = 7\n",
        )
        .unwrap();
        let c = RunConfig::from_toml_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.problem, "stokes");
        assert_eq!(c.steps, 42);
        assert!(c.validate);
        assert_eq!(c.seed, 7);
        assert_eq!(c.strategy, "zcs"); // default preserved
    }

    #[test]
    fn unknown_key_rejected() {
        let dir = std::env::temp_dir().join("zcs_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.toml");
        std::fs::write(&path, "bogus = 3\n").unwrap();
        assert!(RunConfig::from_toml_file(path.to_str().unwrap()).is_err());
    }
}
