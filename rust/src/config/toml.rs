//! Minimal-TOML parser: tables, key = value (string / int / float / bool /
//! flat array), `#` comments.  Covers `configs/*.toml`; nothing more.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub enum TomlError {
    Line(usize, String),
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let Self::Line(ln, msg) = self;
        write!(f, "line {ln}: {msg}")
    }
}

impl std::error::Error for TomlError {}

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
    Table(BTreeMap<String, TomlValue>),
}

/// Parse a TOML document into a root table.
pub fn parse_toml(text: &str) -> Result<TomlValue, TomlError> {
    let mut root: BTreeMap<String, TomlValue> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (ln0, raw) in text.lines().enumerate() {
        let ln = ln0 + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix('[') {
            let header = header
                .strip_suffix(']')
                .ok_or_else(|| TomlError::Line(ln, "missing ']'".into()))?;
            current_path = header.split('.').map(|p| p.trim().to_string()).collect();
            if current_path.iter().any(|p| p.is_empty()) {
                return Err(TomlError::Line(ln, "empty table name".into()));
            }
            // ensure the table exists
            table_at(&mut root, &current_path, ln)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| TomlError::Line(ln, "expected key = value".into()))?;
        let key = line[..eq].trim().trim_matches('"').to_string();
        if key.is_empty() {
            return Err(TomlError::Line(ln, "empty key".into()));
        }
        let value = parse_value(line[eq + 1..].trim(), ln)?;
        let table = table_at(&mut root, &current_path, ln)?;
        table.insert(key, value);
    }
    Ok(TomlValue::Table(root))
}

fn strip_comment(line: &str) -> &str {
    // respect # inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn table_at<'a>(
    root: &'a mut BTreeMap<String, TomlValue>,
    path: &[String],
    ln: usize,
) -> Result<&'a mut BTreeMap<String, TomlValue>, TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| TomlValue::Table(BTreeMap::new()));
        match entry {
            TomlValue::Table(t) => cur = t,
            _ => return Err(TomlError::Line(ln, format!("{part} is not a table"))),
        }
    }
    Ok(cur)
}

fn parse_value(s: &str, ln: usize) -> Result<TomlValue, TomlError> {
    if let Some(inner) = s.strip_prefix('"') {
        let end = inner
            .find('"')
            .ok_or_else(|| TomlError::Line(ln, "unterminated string".into()))?;
        return Ok(TomlValue::Str(inner[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| TomlError::Line(ln, "unterminated array".into()))?;
        let mut items = Vec::new();
        for tok in inner.split(',') {
            let tok = tok.trim();
            if !tok.is_empty() {
                items.push(parse_value(tok, ln)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(TomlError::Line(ln, format!("cannot parse value {s:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get<'a>(v: &'a TomlValue, key: &str) -> &'a TomlValue {
        match v {
            TomlValue::Table(t) => &t[key],
            _ => panic!("not a table"),
        }
    }

    #[test]
    fn scalars_and_comments() {
        let v = parse_toml("a = 1  # comment\nb = \"x # not comment\"\nc = 2.5\nd = true\n")
            .unwrap();
        assert_eq!(get(&v, "a"), &TomlValue::Int(1));
        assert_eq!(get(&v, "b"), &TomlValue::Str("x # not comment".into()));
        assert_eq!(get(&v, "c"), &TomlValue::Float(2.5));
        assert_eq!(get(&v, "d"), &TomlValue::Bool(true));
    }

    #[test]
    fn tables_and_nesting() {
        let v = parse_toml("[a]\nx = 1\n[a.b]\ny = 2\n[c]\nz = 3\n").unwrap();
        assert_eq!(get(&get(&v, "a"), "x"), &TomlValue::Int(1));
        assert_eq!(get(&get(&get(&v, "a"), "b"), "y"), &TomlValue::Int(2));
        assert_eq!(get(&get(&v, "c"), "z"), &TomlValue::Int(3));
    }

    #[test]
    fn arrays() {
        let v = parse_toml("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\n").unwrap();
        assert_eq!(
            get(&v, "xs"),
            &TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
        assert_eq!(
            get(&v, "ys"),
            &TomlValue::Array(vec![
                TomlValue::Str("a".into()),
                TomlValue::Str("b".into())
            ])
        );
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = parse_toml("good = 1\nbad line\n").unwrap_err();
        assert!(matches!(err, TomlError::Line(2, _)));
        assert!(parse_toml("x = @@\n").is_err());
        assert!(parse_toml("[unclosed\n").is_err());
    }
}
