//! Independent numerical truth for validating the trained operators.
//!
//! The paper validates its physics-only-trained DeepONets against reference
//! solutions (analytic series for Kirchhoff-Love, FreeFEM++ for Stokes,
//! standard solvers for reaction-diffusion and Burgers).  These modules are
//! the in-repo substrates standing in for those external tools -- see
//! DESIGN.md "Hardware adaptation & substitutions".
//!
//! Every solver takes the *same* input-function representation the sampler
//! produces and returns fields on caller-chosen evaluation points, so the
//! coordinator can compute the paper's relative-L2 validation error
//! directly against the PJRT `forward` artifact output.

mod burgers;
mod kirchhoff;
mod reaction_diffusion;
mod stokes;
mod tridiag;

pub use burgers::BurgersSolver;
pub use kirchhoff::KirchhoffSolver;
pub use reaction_diffusion::ReactionDiffusionSolver;
pub use stokes::{StokesFields, StokesSolver};
pub use tridiag::thomas_solve;

/// Bilinear interpolation helper on a regular `nx x ny` grid over `[0,1]^2`
/// (row-major in the second coordinate).
pub(crate) fn bilinear(grid: &[f64], nx: usize, ny: usize, x: f64, y: f64) -> f64 {
    let hx = 1.0 / (nx - 1) as f64;
    let hy = 1.0 / (ny - 1) as f64;
    let x = x.clamp(0.0, 1.0);
    let y = y.clamp(0.0, 1.0);
    let i = ((x / hx) as usize).min(nx - 2);
    let j = ((y / hy) as usize).min(ny - 2);
    let tx = (x - i as f64 * hx) / hx;
    let ty = (y - j as f64 * hy) / hy;
    let v00 = grid[i * ny + j];
    let v10 = grid[(i + 1) * ny + j];
    let v01 = grid[i * ny + j + 1];
    let v11 = grid[(i + 1) * ny + j + 1];
    v00 * (1.0 - tx) * (1.0 - ty) + v10 * tx * (1.0 - ty) + v01 * (1.0 - tx) * ty + v11 * tx * ty
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bilinear_exact_on_linear_field() {
        // f(x, y) = 2x + 3y is reproduced exactly by bilinear interpolation
        let (nx, ny) = (5, 4);
        let mut grid = vec![0.0; nx * ny];
        for i in 0..nx {
            for j in 0..ny {
                let x = i as f64 / (nx - 1) as f64;
                let y = j as f64 / (ny - 1) as f64;
                grid[i * ny + j] = 2.0 * x + 3.0 * y;
            }
        }
        for &(x, y) in &[(0.13, 0.77), (0.5, 0.5), (0.99, 0.01), (0.0, 1.0)] {
            let v = bilinear(&grid, nx, ny, x, y);
            assert!((v - (2.0 * x + 3.0 * y)).abs() < 1e-12, "({x},{y})");
        }
    }
}
