//! Reference solver for lid-driven Stokes flow (paper eq. 20) -- the in-repo
//! substitute for the paper's FreeFEM++ truth.
//!
//! Vorticity-streamfunction formulation on the unit square:
//!
//! ```text
//! laplacian(omega) = 0          (Stokes: vorticity is harmonic)
//! laplacian(psi)   = -omega
//! u = psi_y,  v = -psi_x
//! ```
//!
//! Wall vorticity comes from Thom's formula; the coupled system is relaxed
//! with Gauss-Seidel/SOR until the wall-vorticity update stalls.  Pressure is
//! recovered from the momentum equations (`p_x = -mu omega_y`,
//! `p_y = mu omega_x`) by path integration from the bottom-left corner, then
//! shifted so that the *bottom edge* has zero mean -- matching the paper's
//! gauge `p(x, 0) = 0` as closely as a true cavity solution allows (the
//! paper's bottom-pressure pin only fixes the constant; see EXPERIMENTS.md).

pub struct StokesSolver {
    pub viscosity: f64,
    pub n: usize,
    pub max_iters: usize,
    pub tol: f64,
}

impl Default for StokesSolver {
    fn default() -> Self {
        Self { viscosity: 0.01, n: 96, max_iters: 40_000, tol: 1e-10 }
    }
}

/// Velocity + pressure fields on the solver's `n x n` grid (x-major).
pub struct StokesFields {
    pub n: usize,
    pub u: Vec<f64>,
    pub v: Vec<f64>,
    pub p: Vec<f64>,
}

impl StokesSolver {
    /// Solve for a lid velocity `u1` sampled on `n` equally spaced x-points.
    pub fn solve(&self, lid: &[f64]) -> StokesFields {
        let n = self.n;
        assert_eq!(lid.len(), n);
        let h = 1.0 / (n - 1) as f64;
        let idx = |i: usize, j: usize| i * n + j; // j is the y index

        let mut psi = vec![0.0; n * n];
        let mut om = vec![0.0; n * n];
        // Plain Gauss-Seidel on the interiors; the outer omega<->psi<->wall-BC
        // coupling is stabilised by under-relaxing Thom's formula (beta).
        let beta = 0.5;
        let inner_sweeps = 4;

        let mut last_psi_norm = f64::INFINITY;
        for it in 0..self.max_iters {
            // 1. wall vorticity by Thom's formula (psi = 0 on all walls),
            //    under-relaxed for stability of the coupled iteration
            for i in 0..n {
                let thom_bot = -2.0 * psi[idx(i, 1)] / (h * h);
                let thom_top = -2.0 * psi[idx(i, n - 2)] / (h * h) - 2.0 * lid[i] / h;
                let thom_left = -2.0 * psi[idx(1, i)] / (h * h);
                let thom_right = -2.0 * psi[idx(n - 2, i)] / (h * h);
                om[idx(i, 0)] += beta * (thom_bot - om[idx(i, 0)]);
                om[idx(i, n - 1)] += beta * (thom_top - om[idx(i, n - 1)]);
                om[idx(0, i)] += beta * (thom_left - om[idx(0, i)]);
                om[idx(n - 1, i)] += beta * (thom_right - om[idx(n - 1, i)]);
            }
            // 2. Gauss-Seidel sweeps on laplacian(omega) = 0
            for _ in 0..inner_sweeps {
                for i in 1..n - 1 {
                    for j in 1..n - 1 {
                        let nb = om[idx(i - 1, j)] + om[idx(i + 1, j)] + om[idx(i, j - 1)]
                            + om[idx(i, j + 1)];
                        om[idx(i, j)] = 0.25 * nb;
                    }
                }
            }
            // 3. Gauss-Seidel sweeps on laplacian(psi) = -omega
            for _ in 0..inner_sweeps {
                for i in 1..n - 1 {
                    for j in 1..n - 1 {
                        let nb = psi[idx(i - 1, j)] + psi[idx(i + 1, j)] + psi[idx(i, j - 1)]
                            + psi[idx(i, j + 1)];
                        psi[idx(i, j)] = 0.25 * (nb + h * h * om[idx(i, j)]);
                    }
                }
            }
            // convergence: psi norm stalls
            if it % 50 == 49 {
                let psi_norm: f64 = psi.iter().map(|v| v * v).sum();
                if (psi_norm - last_psi_norm).abs() <= self.tol * psi_norm.max(1e-30) {
                    break;
                }
                last_psi_norm = psi_norm;
            }
        }

        // velocities from psi (central differences; one-sided at walls gives
        // the BC values directly, so just impose them)
        let mut u = vec![0.0; n * n];
        let mut v = vec![0.0; n * n];
        for i in 1..n - 1 {
            for j in 1..n - 1 {
                u[idx(i, j)] = (psi[idx(i, j + 1)] - psi[idx(i, j - 1)]) / (2.0 * h);
                v[idx(i, j)] = -(psi[idx(i + 1, j)] - psi[idx(i - 1, j)]) / (2.0 * h);
            }
        }
        for i in 0..n {
            u[idx(i, n - 1)] = lid[i]; // moving lid
        }

        // pressure by path integration of grad p = mu (-omega_y, omega_x):
        // along the bottom edge first, then up each column
        let mu = self.viscosity;
        let mut p = vec![0.0; n * n];
        for i in 1..n {
            // p_x = -mu omega_y at (i-1/2, 0); one-sided omega_y at the wall
            let wy_a = (om[idx(i - 1, 1)] - om[idx(i - 1, 0)]) / h;
            let wy_b = (om[idx(i, 1)] - om[idx(i, 0)]) / h;
            p[idx(i, 0)] = p[idx(i - 1, 0)] - mu * 0.5 * (wy_a + wy_b) * h;
        }
        for i in 0..n {
            for j in 1..n {
                // p_y = mu omega_x at (i, j-1/2); central omega_x where possible
                let wx = |ii: usize, jj: usize| -> f64 {
                    if ii == 0 {
                        (om[idx(1, jj)] - om[idx(0, jj)]) / h
                    } else if ii == n - 1 {
                        (om[idx(n - 1, jj)] - om[idx(n - 2, jj)]) / h
                    } else {
                        (om[idx(ii + 1, jj)] - om[idx(ii - 1, jj)]) / (2.0 * h)
                    }
                };
                p[idx(i, j)] = p[idx(i, j - 1)] + mu * 0.5 * (wx(i, j - 1) + wx(i, j)) * h;
            }
        }
        // gauge: zero mean on the bottom edge (paper pins p(x,0) = 0)
        let bottom_mean: f64 = (0..n).map(|i| p[idx(i, 0)]).sum::<f64>() / n as f64;
        for q in p.iter_mut() {
            *q -= bottom_mean;
        }

        StokesFields { n, u, v, p }
    }
}

impl StokesFields {
    /// Bilinear evaluation of (u, v, p) at an arbitrary point.
    pub fn at(&self, x: f64, y: f64) -> (f64, f64, f64) {
        let f = |g: &[f64]| super::bilinear(g, self.n, self.n, x, y);
        (f(&self.u), f(&self.v), f(&self.p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parabolic_lid(n: usize) -> Vec<f64> {
        (0..n)
            .map(|i| {
                let x = i as f64 / (n - 1) as f64;
                x * (1.0 - x)
            })
            .collect()
    }

    #[test]
    fn zero_lid_gives_rest() {
        let s = StokesSolver { n: 32, max_iters: 2000, ..Default::default() };
        let f = s.solve(&vec![0.0; 32]);
        assert!(f.u.iter().all(|&v| v.abs() < 1e-12));
        assert!(f.v.iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn lid_velocity_imposed() {
        let s = StokesSolver { n: 48, max_iters: 8000, ..Default::default() };
        let lid = parabolic_lid(48);
        let f = s.solve(&lid);
        for i in 0..48 {
            assert_eq!(f.u[i * 48 + 47], lid[i]);
        }
    }

    #[test]
    fn walls_are_no_slip() {
        let s = StokesSolver { n: 48, max_iters: 8000, ..Default::default() };
        let f = s.solve(&parabolic_lid(48));
        for i in 0..48 {
            assert_eq!(f.u[i * 48], 0.0); // bottom
            assert_eq!(f.v[i * 48], 0.0);
            assert_eq!(f.u[i], 0.0); // left column (i = 0 fixed, j = i)
            assert_eq!(f.v[47 * 48 + i], 0.0); // right
        }
    }

    #[test]
    fn interior_flow_develops_and_circulates() {
        let s = StokesSolver { n: 64, max_iters: 20_000, ..Default::default() };
        let f = s.solve(&parabolic_lid(64));
        // u just under the lid should follow the lid; deeper it reverses
        let mid = 32usize;
        let near_top = f.u[mid * 64 + 58];
        let lower = f.u[mid * 64 + 16];
        assert!(near_top > 0.01, "near-lid u = {near_top}");
        assert!(lower < 0.0, "return-flow u = {lower}");
    }

    #[test]
    fn mass_conservation_in_interior() {
        // div(u) ~ 0 at a few interior points via central differences
        let s = StokesSolver { n: 64, max_iters: 20_000, ..Default::default() };
        let f = s.solve(&parabolic_lid(64));
        let n = 64;
        let h = 1.0 / 63.0;
        let umax = f.u.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        for &(i, j) in &[(20usize, 20usize), (32, 40), (45, 25)] {
            let dudx = (f.u[(i + 1) * n + j] - f.u[(i - 1) * n + j]) / (2.0 * h);
            let dvdy = (f.v[i * n + j + 1] - f.v[i * n + j - 1]) / (2.0 * h);
            assert!(
                (dudx + dvdy).abs() < 0.05 * umax / h * h, // O(h) of the velocity scale
                "div at ({i},{j}) = {}",
                dudx + dvdy
            );
        }
    }

    #[test]
    fn pressure_gauge_zero_mean_bottom() {
        let s = StokesSolver { n: 48, max_iters: 8000, ..Default::default() };
        let f = s.solve(&parabolic_lid(48));
        let mean: f64 = (0..48).map(|i| f.p[i * 48]).sum::<f64>() / 48.0;
        assert!(mean.abs() < 1e-12);
    }

    #[test]
    fn symmetric_lid_gives_symmetric_fields() {
        // u1(x) = x(1-x) is symmetric about x = 1/2: u must be symmetric,
        // v antisymmetric.
        let s = StokesSolver { n: 49, max_iters: 20_000, ..Default::default() };
        let f = s.solve(&parabolic_lid(49));
        let n = 49;
        for j in (4..n - 4).step_by(11) {
            for i in 1..n / 2 {
                let ui = f.u[i * n + j];
                let um = f.u[(n - 1 - i) * n + j];
                assert!((ui - um).abs() < 5e-3, "u sym ({i},{j}): {ui} vs {um}");
                let vi = f.v[i * n + j];
                let vm = f.v[(n - 1 - i) * n + j];
                assert!((vi + vm).abs() < 5e-3, "v antisym ({i},{j}): {vi} vs {vm}");
            }
        }
    }
}
