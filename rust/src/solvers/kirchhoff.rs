//! Analytic reference for the Kirchhoff-Love plate (paper eq. 18/19).
//!
//! For the bi-trigonometric load
//! `q(x,y) = sum_rs c_rs sin(r pi x) sin(s pi y)` on the unit square with
//! simply-supported edges, the Germain-Lagrange equation
//! `u_xxxx + 2 u_xxyy + u_yyyy = q / D` has the exact series solution
//!
//! ```text
//! u(x,y) = sum_rs  c_rs / (D pi^4 (r^2 + s^2)^2)  sin(r pi x) sin(s pi y)
//! ```
//!
//! (each sine mode is an eigenfunction of the biharmonic operator with
//! eigenvalue `pi^4 (r^2+s^2)^2`).  This is the same closed form the paper
//! uses for validation.

pub struct KirchhoffSolver {
    pub rigidity: f64,
    pub r_modes: usize,
    pub s_modes: usize,
}

impl Default for KirchhoffSolver {
    fn default() -> Self {
        Self { rigidity: 0.01, r_modes: 10, s_modes: 10 }
    }
}

impl KirchhoffSolver {
    /// Deflection at arbitrary points for coefficient matrix `c`
    /// (row-major `r_modes x s_modes`).
    pub fn solve_at(&self, c: &[f64], pts: &[(f64, f64)]) -> Vec<f64> {
        assert_eq!(c.len(), self.r_modes * self.s_modes);
        let pi = std::f64::consts::PI;
        let pi4 = pi.powi(4);
        pts.iter()
            .map(|&(x, y)| {
                let mut u = 0.0;
                for r in 1..=self.r_modes {
                    let sx = (r as f64 * pi * x).sin();
                    for s in 1..=self.s_modes {
                        let k = (r * r + s * s) as f64;
                        u += c[(r - 1) * self.s_modes + (s - 1)]
                            / (self.rigidity * pi4 * k * k)
                            * sx
                            * (s as f64 * pi * y).sin();
                    }
                }
                u
            })
            .collect()
    }

    /// The load itself at arbitrary points (for residual checks).
    pub fn source_at(&self, c: &[f64], pts: &[(f64, f64)]) -> Vec<f64> {
        let pi = std::f64::consts::PI;
        pts.iter()
            .map(|&(x, y)| {
                let mut q = 0.0;
                for r in 1..=self.r_modes {
                    let sx = (r as f64 * pi * x).sin();
                    for s in 1..=self.s_modes {
                        q += c[(r - 1) * self.s_modes + (s - 1)] * sx * (s as f64 * pi * y).sin();
                    }
                }
                q
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_mode_closed_form() {
        // c_11 only: u = c / (D pi^4 * 4) sin(pi x) sin(pi y)
        let s = KirchhoffSolver::default();
        let mut c = vec![0.0; 100];
        c[0] = 2.0;
        let pi = std::f64::consts::PI;
        let u = s.solve_at(&c, &[(0.5, 0.5)]);
        let want = 2.0 / (0.01 * pi.powi(4) * 4.0);
        assert!((u[0] - want).abs() < 1e-12, "{} vs {want}", u[0]);
    }

    #[test]
    fn vanishes_on_boundary() {
        let s = KirchhoffSolver::default();
        let mut rng = crate::rng::Pcg64::seeded(13);
        let c = rng.normals(100);
        let pts = vec![(0.0, 0.3), (1.0, 0.9), (0.4, 0.0), (0.7, 1.0)];
        for u in s.solve_at(&c, &pts) {
            assert!(u.abs() < 1e-10);
        }
    }

    #[test]
    fn satisfies_biharmonic_equation_fd_check() {
        // verify u_xxxx + 2 u_xxyy + u_yyyy == q / D by 5-point 4th-order FD
        let s = KirchhoffSolver::default();
        let mut rng = crate::rng::Pcg64::seeded(14);
        // restrict to modes r, s <= 3: the 2nd-order FD stencil's relative
        // truncation error is O((r pi h)^2), ~10% at mode 10 but ~1% here
        let mut c = rng.normals(100);
        for r in 0..10 {
            for sdx in 0..10 {
                if r >= 3 || sdx >= 3 {
                    c[r * 10 + sdx] = 0.0;
                }
            }
        }
        let h = 1e-2;
        let (x0, y0) = (0.43, 0.61);
        let u = |x: f64, y: f64| s.solve_at(&c, &[(x, y)])[0];
        // 4th derivative stencils
        let d4x = (u(x0 - 2.0 * h, y0) - 4.0 * u(x0 - h, y0) + 6.0 * u(x0, y0)
            - 4.0 * u(x0 + h, y0)
            + u(x0 + 2.0 * h, y0))
            / h.powi(4);
        let d4y = (u(x0, y0 - 2.0 * h) - 4.0 * u(x0, y0 - h) + 6.0 * u(x0, y0)
            - 4.0 * u(x0, y0 + h)
            + u(x0, y0 + 2.0 * h))
            / h.powi(4);
        let mut d2x2y = 0.0;
        for (dx, wx) in [(-1.0, 1.0), (0.0, -2.0), (1.0, 1.0)] {
            for (dy, wy) in [(-1.0, 1.0), (0.0, -2.0), (1.0, 1.0)] {
                d2x2y += wx * wy * u(x0 + dx * h, y0 + dy * h);
            }
        }
        d2x2y /= h.powi(4);
        let lhs = d4x + 2.0 * d2x2y + d4y;
        let rhs = s.source_at(&c, &[(x0, y0)])[0] / s.rigidity;
        assert!(
            (lhs - rhs).abs() < 2e-2 * rhs.abs().max(1.0),
            "biharmonic residual: {lhs} vs {rhs}"
        );
    }

    #[test]
    fn linearity_in_coefficients() {
        let s = KirchhoffSolver::default();
        let mut rng = crate::rng::Pcg64::seeded(15);
        let c1 = rng.normals(100);
        let c2 = rng.normals(100);
        let csum: Vec<f64> = c1.iter().zip(&c2).map(|(a, b)| a + b).collect();
        let pts = vec![(0.21, 0.77), (0.5, 0.5)];
        let u1 = s.solve_at(&c1, &pts);
        let u2 = s.solve_at(&c2, &pts);
        let us = s.solve_at(&csum, &pts);
        for i in 0..pts.len() {
            assert!((us[i] - u1[i] - u2[i]).abs() < 1e-12);
        }
    }
}
