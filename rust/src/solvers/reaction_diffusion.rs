//! Reference solver for the reaction-diffusion operator (paper eq. 16):
//!
//! ```text
//! u_t - D u_xx + k u^2 - f(x) = 0,   x in (0,1), t in (0,1)
//! u(x, 0) = 0;  u(0, t) = u(1, t) = 0
//! ```
//!
//! Semi-implicit (IMEX) scheme: diffusion Crank-Nicolson (unconditionally
//! stable, tridiagonal Thomas solve per step), reaction + source explicit.
//! Second-order in space, first-order in time -- ample for the validation
//! tolerance (the trained operators sit at ~8% relative error; paper
//! Table 1).

use super::{bilinear, tridiag::thomas_solve};

pub struct ReactionDiffusionSolver {
    pub diff_coef: f64,
    pub react_coef: f64,
    pub nx: usize,
    pub nt: usize,
}

impl Default for ReactionDiffusionSolver {
    fn default() -> Self {
        Self { diff_coef: 0.01, react_coef: 0.01, nx: 128, nt: 512 }
    }
}

impl ReactionDiffusionSolver {
    /// Solve for one source function `f` given as values on `nx` equally
    /// spaced points of `[0, 1]`.  Returns the space-time field as a
    /// row-major `nx x nt` grid (x-major, then t), covering `[0,1]^2`.
    pub fn solve_grid(&self, f: &[f64]) -> Vec<f64> {
        let (nx, nt) = (self.nx, self.nt);
        assert_eq!(f.len(), nx, "source must be sampled on the solver grid");
        let h = 1.0 / (nx - 1) as f64;
        let dt = 1.0 / (nt - 1) as f64;
        let r = self.diff_coef * dt / (h * h);

        // Crank-Nicolson matrices on interior nodes (Dirichlet ends)
        let ni = nx - 2;
        let sub = vec![-0.5 * r; ni - 1];
        let diag = vec![1.0 + r; ni];
        let sup = vec![-0.5 * r; ni - 1];

        let mut u = vec![0.0; nx]; // u(x, 0) = 0
        let mut out = vec![0.0; nx * nt];
        for j in 1..nt {
            let mut rhs = vec![0.0; ni];
            for i in 0..ni {
                let xi = i + 1;
                let lap = u[xi - 1] - 2.0 * u[xi] + u[xi + 1];
                let react = -self.react_coef * u[xi] * u[xi] + f[xi];
                rhs[i] = u[xi] + 0.5 * r * lap + dt * react;
            }
            let ui = thomas_solve(&sub, &diag, &sup, &rhs);
            for i in 0..ni {
                u[i + 1] = ui[i];
            }
            u[0] = 0.0;
            u[nx - 1] = 0.0;
            for i in 0..nx {
                out[i * nt + j] = u[i];
            }
        }
        out
    }

    /// Evaluate the solution at arbitrary `(x, t)` points (bilinear).
    pub fn solve_at(&self, f: &[f64], pts: &[(f64, f64)]) -> Vec<f64> {
        let grid = self.solve_grid(f);
        pts.iter()
            .map(|&(x, t)| bilinear(&grid, self.nx, self.nt, x, t))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_source_gives_zero_solution() {
        let s = ReactionDiffusionSolver::default();
        let grid = s.solve_grid(&vec![0.0; s.nx]);
        assert!(grid.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn boundary_and_initial_conditions_hold() {
        let s = ReactionDiffusionSolver { nx: 64, nt: 128, ..Default::default() };
        let f: Vec<f64> = (0..64)
            .map(|i| (std::f64::consts::PI * i as f64 / 63.0).sin())
            .collect();
        let grid = s.solve_grid(&f);
        for j in 0..s.nt {
            assert_eq!(grid[j], 0.0); // x = 0
            assert_eq!(grid[(s.nx - 1) * s.nt + j], 0.0); // x = 1
        }
        for i in 0..s.nx {
            assert_eq!(grid[i * s.nt], 0.0); // t = 0
        }
    }

    #[test]
    fn converges_to_linear_steady_state() {
        // Without reaction (k = 0), steady state solves D u'' = -f.
        // For f = sin(pi x): u_inf = sin(pi x) / (D pi^2).
        let s = ReactionDiffusionSolver {
            react_coef: 0.0,
            diff_coef: 0.5, // fast diffusion reaches steady state within t<=1
            nx: 96,
            nt: 768,
        };
        let pi = std::f64::consts::PI;
        let f: Vec<f64> = (0..96).map(|i| (pi * i as f64 / 95.0).sin()).collect();
        let grid = s.solve_grid(&f);
        for i in [20, 48, 70] {
            let x = i as f64 / 95.0;
            let want = (pi * x).sin() / (0.5 * pi * pi);
            let got = grid[i * s.nt + s.nt - 1];
            assert!((got - want).abs() < 2e-3 * want.abs().max(1.0), "x={x}: {got} vs {want}");
        }
    }

    #[test]
    fn grid_refinement_converges() {
        let f = |nx: usize| -> Vec<f64> {
            (0..nx).map(|i| {
                let x = i as f64 / (nx - 1) as f64;
                (2.0 * std::f64::consts::PI * x).sin() + 1.0 - (x - 0.5).powi(2)
            }).collect()
        };
        let coarse = ReactionDiffusionSolver { nx: 48, nt: 128, ..Default::default() };
        let fine = ReactionDiffusionSolver { nx: 192, nt: 512, ..Default::default() };
        let pts: Vec<(f64, f64)> = vec![(0.3, 0.5), (0.6, 0.9), (0.5, 1.0)];
        let a = coarse.solve_at(&f(48), &pts);
        let b = fine.solve_at(&f(192), &pts);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 5e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn reaction_term_damps_solution() {
        let pi = std::f64::consts::PI;
        let f: Vec<f64> = (0..64).map(|i| 5.0 * (pi * i as f64 / 63.0).sin()).collect();
        let without = ReactionDiffusionSolver { nx: 64, nt: 256, react_coef: 0.0, ..Default::default() };
        let with = ReactionDiffusionSolver { nx: 64, nt: 256, react_coef: 5.0, ..Default::default() };
        let a = without.solve_grid(&f);
        let b = with.solve_grid(&f);
        let max_a = a.iter().fold(0.0f64, |m, &v| m.max(v));
        let max_b = b.iter().fold(0.0f64, |m, &v| m.max(v));
        assert!(max_b < max_a, "{max_b} !< {max_a}");
    }
}
