//! Thomas algorithm for tridiagonal systems (the Crank-Nicolson work-horse).

/// Solve the tridiagonal system with sub-diagonal `a` (len n-1), diagonal
/// `b` (len n), super-diagonal `c` (len n-1) and right-hand side `d`.
pub fn thomas_solve(a: &[f64], b: &[f64], c: &[f64], d: &[f64]) -> Vec<f64> {
    let n = b.len();
    assert_eq!(a.len(), n - 1);
    assert_eq!(c.len(), n - 1);
    assert_eq!(d.len(), n);
    let mut cp = vec![0.0; n - 1];
    let mut dp = vec![0.0; n];
    cp[0] = c[0] / b[0];
    dp[0] = d[0] / b[0];
    for i in 1..n {
        let m = b[i] - a[i - 1] * if i - 1 < n - 1 { cp[i - 1] } else { 0.0 };
        if i < n - 1 {
            cp[i] = c[i] / m;
        }
        dp[i] = (d[i] - a[i - 1] * dp[i - 1]) / m;
    }
    let mut x = vec![0.0; n];
    x[n - 1] = dp[n - 1];
    for i in (0..n - 1).rev() {
        x[i] = dp[i] - cp[i] * x[i + 1];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_identity() {
        let x = thomas_solve(&[0.0, 0.0], &[1.0, 1.0, 1.0], &[0.0, 0.0], &[3.0, 4.0, 5.0]);
        assert_eq!(x, vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn solves_laplacian_like_system() {
        // [2 -1 0; -1 2 -1; 0 -1 2] x = [1, 0, 1] -> x = [1, 1, 1]
        let x = thomas_solve(&[-1.0, -1.0], &[2.0, 2.0, 2.0], &[-1.0, -1.0], &[1.0, 0.0, 1.0]);
        for v in x {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn matches_dense_solution() {
        // random diagonally dominant system, compare against naive Gauss
        let n = 12;
        let mut rng = crate::rng::Pcg64::seeded(77);
        let a: Vec<f64> = rng.normals(n - 1);
        let c: Vec<f64> = rng.normals(n - 1);
        let b: Vec<f64> = (0..n).map(|i| {
            4.0 + rng.uniform()
                + if i > 0 { a[i - 1].abs() } else { 0.0 }
                + if i < n - 1 { c[i].abs() } else { 0.0 }
        }).collect();
        let d: Vec<f64> = rng.normals(n);
        let x = thomas_solve(&a, &b, &c, &d);
        // residual check
        for i in 0..n {
            let mut r = b[i] * x[i] - d[i];
            if i > 0 {
                r += a[i - 1] * x[i - 1];
            }
            if i < n - 1 {
                r += c[i] * x[i + 1];
            }
            assert!(r.abs() < 1e-10, "row {i}: {r}");
        }
    }
}
