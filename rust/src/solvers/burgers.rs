//! Reference solver for the periodic Burgers operator (paper eq. 17):
//!
//! ```text
//! u_t + u u_x - nu u_xx = 0,   x in (0,1), t in (0,1),  nu = 0.01
//! u(x, 0) = u0(x);  u(0, t) = u(1, t)   (periodic)
//! ```
//!
//! Scheme: method of lines on a periodic grid; conservative flux form
//! `u u_x = (u^2/2)_x` with central differences for both terms and RK2
//! (Heun) time stepping under a CFL-limited dt.  nu = 0.01 keeps shocks
//! smooth enough for central differencing at the resolutions we use.

use super::bilinear;

pub struct BurgersSolver {
    pub viscosity: f64,
    pub nx: usize,
    pub nt_store: usize,
}

impl Default for BurgersSolver {
    fn default() -> Self {
        Self { viscosity: 0.01, nx: 256, nt_store: 128 }
    }
}

impl BurgersSolver {
    fn rhs(&self, u: &[f64], h: f64) -> Vec<f64> {
        let n = u.len();
        let nu = self.viscosity;
        let mut d = vec![0.0; n];
        for i in 0..n {
            let im = (i + n - 1) % n;
            let ip = (i + 1) % n;
            let flux = (u[ip] * u[ip] - u[im] * u[im]) / (4.0 * h); // (u^2/2)_x central
            let diff = nu * (u[ip] - 2.0 * u[i] + u[im]) / (h * h);
            d[i] = -flux + diff;
        }
        d
    }

    /// Solve for one initial condition given on the periodic grid
    /// (`nx` points, x_i = i/nx -- note x = 1 wraps to x = 0).
    /// Returns `nx x nt_store` (x-major) snapshots at equally spaced times.
    pub fn solve_grid(&self, u0: &[f64]) -> Vec<f64> {
        let (nx, nts) = (self.nx, self.nt_store);
        assert_eq!(u0.len(), nx);
        let h = 1.0 / nx as f64;
        let umax = u0.iter().fold(0.1f64, |m, &v| m.max(v.abs()));
        // CFL: advective + diffusive
        let dt_adv = 0.4 * h / umax;
        let dt_diff = 0.4 * h * h / (2.0 * self.viscosity);
        let dt = dt_adv.min(dt_diff);
        let steps_total = (1.0 / dt).ceil() as usize;
        let dt = 1.0 / steps_total as f64;

        let mut u = u0.to_vec();
        let mut out = vec![0.0; nx * nts];
        for i in 0..nx {
            out[i * nts] = u[i];
        }
        let mut next_snap = 1usize;
        for s in 1..=steps_total {
            // Heun RK2
            let k1 = self.rhs(&u, h);
            let u1: Vec<f64> = u.iter().zip(&k1).map(|(a, b)| a + dt * b).collect();
            let k2 = self.rhs(&u1, h);
            for i in 0..nx {
                u[i] += 0.5 * dt * (k1[i] + k2[i]);
            }
            let t = s as f64 * dt;
            while next_snap < nts && t + 1e-12 >= next_snap as f64 / (nts - 1) as f64 {
                for i in 0..nx {
                    out[i * nts + next_snap] = u[i];
                }
                next_snap += 1;
            }
        }
        out
    }

    /// Evaluate at arbitrary `(x, t)` points (periodic in x, bilinear in the
    /// stored snapshots).
    pub fn solve_at(&self, u0: &[f64], pts: &[(f64, f64)]) -> Vec<f64> {
        let grid = self.solve_grid(u0);
        // extend the periodic grid with the wrap column for interpolation
        let (nx, nts) = (self.nx, self.nt_store);
        let mut ext = vec![0.0; (nx + 1) * nts];
        ext[..nx * nts].copy_from_slice(&grid);
        for j in 0..nts {
            ext[nx * nts + j] = grid[j]; // u(1, t) = u(0, t)
        }
        pts.iter()
            .map(|&(x, t)| bilinear(&ext, nx + 1, nts, x.rem_euclid(1.0), t))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_state_is_invariant() {
        let s = BurgersSolver { nx: 64, ..Default::default() };
        let grid = s.solve_grid(&vec![0.7; 64]);
        for v in grid {
            assert!((v - 0.7).abs() < 1e-10);
        }
    }

    #[test]
    fn viscosity_decays_sine_mode() {
        // For small amplitude, Burgers ~ heat equation: the fundamental mode
        // decays like exp(-nu (2 pi)^2 t).
        let nx = 128;
        let eps = 1e-3;
        let pi2 = 2.0 * std::f64::consts::PI;
        let u0: Vec<f64> = (0..nx).map(|i| eps * (pi2 * i as f64 / nx as f64).sin()).collect();
        let s = BurgersSolver { nx, nt_store: 64, viscosity: 0.01 };
        let grid = s.solve_grid(&u0);
        let amp_end: f64 = (0..nx)
            .map(|i| grid[i * 64 + 63].abs())
            .fold(0.0, f64::max);
        let want = eps * (-0.01 * pi2 * pi2).exp();
        assert!((amp_end - want).abs() < 0.05 * want, "{amp_end} vs {want}");
    }

    #[test]
    fn mass_is_conserved() {
        // periodic Burgers conserves the mean of u
        let nx = 128;
        let u0: Vec<f64> = (0..nx)
            .map(|i| {
                let x = i as f64 / nx as f64;
                0.5 + 0.3 * (2.0 * std::f64::consts::PI * x).sin()
                    + 0.1 * (4.0 * std::f64::consts::PI * x).cos()
            })
            .collect();
        let s = BurgersSolver { nx, nt_store: 16, ..Default::default() };
        let grid = s.solve_grid(&u0);
        let mean0: f64 = (0..nx).map(|i| grid[i * 16]).sum::<f64>() / nx as f64;
        let mean1: f64 = (0..nx).map(|i| grid[i * 16 + 15]).sum::<f64>() / nx as f64;
        assert!((mean0 - mean1).abs() < 1e-6, "{mean0} vs {mean1}");
    }

    #[test]
    fn periodic_wrap_in_solve_at() {
        let nx = 64;
        let u0: Vec<f64> = (0..nx)
            .map(|i| (2.0 * std::f64::consts::PI * i as f64 / nx as f64).cos())
            .collect();
        let s = BurgersSolver { nx, ..Default::default() };
        let v = s.solve_at(&u0, &[(0.0, 0.5), (1.0, 0.5)]);
        assert!((v[0] - v[1]).abs() < 1e-9, "{} vs {}", v[0], v[1]);
    }

    #[test]
    fn refinement_converges() {
        let f = |nx: usize| -> Vec<f64> {
            (0..nx)
                .map(|i| {
                    let x = i as f64 / nx as f64;
                    0.4 * (2.0 * std::f64::consts::PI * x).sin()
                })
                .collect()
        };
        let coarse = BurgersSolver { nx: 96, nt_store: 64, ..Default::default() };
        let fine = BurgersSolver { nx: 384, nt_store: 64, ..Default::default() };
        let pts = vec![(0.25, 0.4), (0.7, 0.8)];
        let a = coarse.solve_at(&f(96), &pts);
        let b = fine.solve_at(&f(384), &pts);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 5e-3, "{x} vs {y}");
        }
    }
}
