//! Declarative command-line parsing (the offline stand-in for `clap`).
//!
//! Supports subcommands, `--flag value` / `--flag=value` options, boolean
//! switches, defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    MissingRequired(String),
    BadValue(String, String, String),
    UnexpectedPositional(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::UnknownOption(name) => write!(f, "unknown option --{name}"),
            Self::MissingValue(name) => write!(f, "option --{name} needs a value"),
            Self::MissingRequired(name) => write!(f, "missing required option --{name}"),
            Self::BadValue(name, value, why) => {
                write!(f, "invalid value {value:?} for --{name}: {why}")
            }
            Self::UnexpectedPositional(arg) => {
                write!(f, "unexpected positional argument {arg:?}")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// One option specification.
#[derive(Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
    pub required: bool,
}

/// A declarative option table + parser.
pub struct Opts {
    program: String,
    about: &'static str,
    specs: Vec<OptSpec>,
}

/// Parsed option values.
pub struct Parsed {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Opts {
    pub fn new(program: &str, about: &'static str) -> Self {
        Self { program: program.to_string(), about, specs: Vec::new() }
    }

    /// Option taking a value, with a default.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: Some(default), is_switch: false, required: false });
        self
    }

    /// Required option taking a value.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_switch: false, required: true });
        self
    }

    /// Boolean switch (present = true).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_switch: true, required: false });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} -- {}\n\noptions:\n", self.program, self.about);
        for spec in &self.specs {
            let kind = if spec.is_switch {
                String::new()
            } else if let Some(d) = spec.default {
                format!(" <value, default {d}>")
            } else {
                " <value, required>".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", spec.name, kind, spec.help));
        }
        s
    }

    /// Parse a raw argument list (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let mut values = BTreeMap::new();
        let mut switches = BTreeMap::new();
        let mut positionals = Vec::new();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                values.insert(spec.name.to_string(), d.to_string());
            }
            if spec.is_switch {
                switches.insert(spec.name.to_string(), false);
            }
        }
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let Some(spec) = self.specs.iter().find(|s| s.name == name) else {
                    return Err(CliError::UnknownOption(name));
                };
                if spec.is_switch {
                    switches.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    values.insert(name, v);
                }
            } else {
                positionals.push(arg.clone());
            }
        }
        for spec in &self.specs {
            if spec.required && !values.contains_key(spec.name) {
                return Err(CliError::MissingRequired(spec.name.to_string()));
            }
        }
        Ok(Parsed { values, switches, positionals })
    }
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.get(name);
        v.parse()
            .map_err(|e: std::num::ParseIntError| CliError::BadValue(name.into(), v.into(), e.to_string()))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.get(name);
        v.parse()
            .map_err(|e: std::num::ParseFloatError| CliError::BadValue(name.into(), v.into(), e.to_string()))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.get(name);
        v.parse()
            .map_err(|e: std::num::ParseIntError| CliError::BadValue(name.into(), v.into(), e.to_string()))
    }

    pub fn switch(&self, name: &str) -> bool {
        *self
            .switches
            .get(name)
            .unwrap_or_else(|| panic!("switch --{name} was not declared"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn opts() -> Opts {
        Opts::new("prog", "test")
            .opt("steps", "100", "number of steps")
            .req("problem", "problem name")
            .switch("verbose", "chatty")
    }

    #[test]
    fn defaults_and_values() {
        let p = opts().parse(&args(&["--problem", "rd"])).unwrap();
        assert_eq!(p.get("steps"), "100");
        assert_eq!(p.get_usize("steps").unwrap(), 100);
        assert_eq!(p.get("problem"), "rd");
        assert!(!p.switch("verbose"));
    }

    #[test]
    fn equals_syntax_and_switch() {
        let p = opts()
            .parse(&args(&["--problem=burgers", "--steps=5", "--verbose"]))
            .unwrap();
        assert_eq!(p.get("problem"), "burgers");
        assert_eq!(p.get_usize("steps").unwrap(), 5);
        assert!(p.switch("verbose"));
    }

    #[test]
    fn positionals_collected() {
        let p = opts().parse(&args(&["train", "--problem", "rd"])).unwrap();
        assert_eq!(p.positionals, vec!["train"]);
    }

    #[test]
    fn missing_required_rejected() {
        assert!(matches!(
            opts().parse(&args(&[])),
            Err(CliError::MissingRequired(_))
        ));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            opts().parse(&args(&["--problem", "rd", "--bogus"])),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            opts().parse(&args(&["--problem"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_numeric_value() {
        let p = opts().parse(&args(&["--problem", "rd", "--steps", "xx"])).unwrap();
        assert!(matches!(p.get_usize("steps"), Err(CliError::BadValue(..))));
    }

    #[test]
    fn usage_mentions_all_options() {
        let u = opts().usage();
        assert!(u.contains("--steps") && u.contains("--problem") && u.contains("--verbose"));
    }
}
