//! One home for `ZCS_*` environment knobs.
//!
//! Every knob (`ZCS_THREADS`, `ZCS_SCHED`, `ZCS_SIMD`, `ZCS_PROFILE`,
//! `ZCS_REPLICAS`) resolves through [`knob`], which gives them all the
//! warn-on-typo fallback `ZCS_SIMD` pioneered: an unset variable yields
//! the default silently, an unparseable value warns once on stderr and
//! *then* yields the default -- a typo can never silently select the
//! behaviour the user tried to exclude, and never aborts a run either.
//!
//! [`parse_knob`] is the pure core (no process environment touched), so
//! the policy is unit-testable without mutating env vars from a threaded
//! test binary.

/// Resolve one knob from an already-read raw value: `None` (unset) gives
/// the default silently; `Some` is trimmed and parsed, and a parse error
/// warns on stderr and falls back to the default.
pub fn parse_knob<T>(
    name: &str,
    raw: Option<&str>,
    default: T,
    parse: impl FnOnce(&str) -> Result<T, String>,
) -> T {
    match raw {
        Some(v) => parse(v.trim()).unwrap_or_else(|e| {
            eprintln!("warning: {name} ignored: {e}");
            default
        }),
        None => default,
    }
}

/// Read `name` from the process environment and resolve it via
/// [`parse_knob`].
pub fn knob<T>(name: &str, default: T, parse: impl FnOnce(&str) -> Result<T, String>) -> T {
    let raw = std::env::var(name).ok();
    parse_knob(name, raw.as_deref(), default, parse)
}

/// Parse a positive count (`>= 1`), for thread and replica budgets.
pub fn parse_count(v: &str) -> Result<usize, String> {
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(format!("{v:?} is not a positive integer")),
    }
}

/// Parse an on/off switch: `1 | true | on` and `0 | false | off | ""`
/// (case-insensitive).
pub fn parse_switch(v: &str) -> Result<bool, String> {
    match v.to_ascii_lowercase().as_str() {
        "1" | "true" | "on" => Ok(true),
        "" | "0" | "false" | "off" => Ok(false),
        other => Err(format!("{other:?} is not a switch; choices: 0, 1, true, false, on, off")),
    }
}

/// The `ZCS_REPLICAS` default: data-parallel replica executors per
/// trainer (clamped to the canonical lane count downstream), else 1.
pub fn default_replicas() -> usize {
    knob("ZCS_REPLICAS", 1, parse_count)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_yields_the_default_without_parsing() {
        let got = parse_knob("ZCS_TEST", None, 7usize, |_| panic!("must not parse"));
        assert_eq!(got, 7);
    }

    #[test]
    fn set_values_are_trimmed_and_parsed() {
        assert_eq!(parse_knob("ZCS_TEST", Some("  3 "), 1usize, parse_count), 3);
        assert_eq!(parse_knob("ZCS_TEST", Some("on"), false, parse_switch), true);
        assert_eq!(parse_knob("ZCS_TEST", Some("OFF"), true, parse_switch), false);
    }

    #[test]
    fn typos_fall_back_to_the_default() {
        // warns on stderr, never panics, never picks a surprise value
        assert_eq!(parse_knob("ZCS_TEST", Some("fuor"), 4usize, parse_count), 4);
        assert_eq!(parse_knob("ZCS_TEST", Some("0"), 2usize, parse_count), 2);
        assert_eq!(parse_knob("ZCS_TEST", Some("yes"), false, parse_switch), false);
    }

    #[test]
    fn count_and_switch_parsers_cover_their_domains() {
        assert_eq!(parse_count("12"), Ok(12));
        assert!(parse_count("0").is_err());
        assert!(parse_count("-1").is_err());
        assert_eq!(parse_switch(""), Ok(false));
        assert_eq!(parse_switch("TRUE"), Ok(true));
        assert!(parse_switch("maybe").is_err());
    }
}
