//! One home for `ZCS_*` environment knobs.
//!
//! Every knob (`ZCS_THREADS`, `ZCS_SCHED`, `ZCS_SIMD`, `ZCS_PROFILE`,
//! `ZCS_REPLICAS`, `ZCS_FAULT`, `ZCS_SANITIZE`, `ZCS_STALL_MS`) resolves
//! through [`knob`], which gives
//! them all the warn-on-typo fallback `ZCS_SIMD` pioneered: an unset
//! variable yields the default silently, an unparseable value warns once
//! on stderr and *then* yields the default -- a typo can never silently
//! select the behaviour the user tried to exclude, and never aborts a
//! run either.
//!
//! [`parse_knob`] is the pure core (no process environment touched), so
//! the policy is unit-testable without mutating env vars from a threaded
//! test binary.  [`knob_reports`] renders every knob's effective value,
//! default and source for the `zcs env` subcommand.
//!
//! `ZCS_FAULT` is the deterministic fault injector behind the
//! crash-safety layer: a comma-separated list of `kind:K` specs.
//! Training faults -- `panic:K` makes the stepping engine panic at step
//! `K`, `nan:K` poisons a gradient buffer with NaN at step `K`,
//! `torn-ckpt:K` truncates the checkpoint written at step `K` mid-file,
//! and `stall:K` freezes one replica driver inside step `K` long enough
//! to trip the all-reduce stall watchdog.
//! Serving faults -- `eval-panic:K` panics the `K`th serve eval attempt,
//! `slow:K` stalls it, and `conn-drop:K` drops the `K`th accepted
//! connection.  Each spec in a [`FaultCell`] fires **exactly once**
//! (process-wide for the environment cell), so the recovery path runs
//! under fault and the rest of the process proceeds normally -- which is
//! what lets CI run the whole test suite with injection enabled.
//!
//! `ZCS_SANITIZE` selects the correctness layer ([`SanitizeMode`]):
//! `off` (zero overhead), `static` (post-compile [Program verification]
//! in release builds too), or `full` (static checks plus the executor's
//! runtime race/NaN tripwires and stall watchdogs).
//!
//! [Program verification]: crate::autodiff::verify

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

/// Resolve one knob from an already-read raw value: `None` (unset) gives
/// the default silently; `Some` is trimmed and parsed, and a parse error
/// warns on stderr and falls back to the default.
pub fn parse_knob<T>(
    name: &str,
    raw: Option<&str>,
    default: T,
    parse: impl FnOnce(&str) -> Result<T, String>,
) -> T {
    match raw {
        Some(v) => parse(v.trim()).unwrap_or_else(|e| {
            eprintln!("warning: {name} ignored: {e}");
            default
        }),
        None => default,
    }
}

/// Read `name` from the process environment and resolve it via
/// [`parse_knob`].
pub fn knob<T>(name: &str, default: T, parse: impl FnOnce(&str) -> Result<T, String>) -> T {
    let raw = std::env::var(name).ok();
    parse_knob(name, raw.as_deref(), default, parse)
}

/// Parse a positive count (`>= 1`), for thread and replica budgets.
pub fn parse_count(v: &str) -> Result<usize, String> {
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(format!("{v:?} is not a positive integer")),
    }
}

/// Parse an on/off switch: `1 | true | on` and `0 | false | off | ""`
/// (case-insensitive).
pub fn parse_switch(v: &str) -> Result<bool, String> {
    match v.to_ascii_lowercase().as_str() {
        "1" | "true" | "on" => Ok(true),
        "" | "0" | "false" | "off" => Ok(false),
        other => Err(format!("{other:?} is not a switch; choices: 0, 1, true, false, on, off")),
    }
}

/// The `ZCS_REPLICAS` default: data-parallel replica executors per
/// trainer (clamped to the canonical lane count downstream), else 1.
pub fn default_replicas() -> usize {
    knob("ZCS_REPLICAS", 1, parse_count)
}

/// How much of the correctness layer is active (`ZCS_SANITIZE`).
///
/// The variants are ordered: `Static` includes everything `Off` skips,
/// `Full` includes everything `Static` does, so call sites gate with
/// `mode >= SanitizeMode::Static`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SanitizeMode {
    /// no checks beyond what debug assertions already do -- the
    /// executor is bit- and allocation-identical to a build without the
    /// sanitizer (pinned by `rust/tests/resident_step.rs`)
    Off,
    /// run the [static Program verifier] over every compiled program,
    /// release builds included (debug builds always verify)
    ///
    /// [static Program verifier]: crate::autodiff::verify
    Static,
    /// static checks plus the runtime sanitizer: the shadow-arena race
    /// tripwire, the per-instruction non-finite tripwire, and the
    /// barrier/dispatcher stall watchdogs ([`env_stall_ms`])
    Full,
}

impl SanitizeMode {
    /// Case-insensitive parse with a choice-listing error.
    pub fn parse(name: &str) -> Result<SanitizeMode, String> {
        match name.to_ascii_lowercase().as_str() {
            "off" => Ok(SanitizeMode::Off),
            "static" => Ok(SanitizeMode::Static),
            "full" => Ok(SanitizeMode::Full),
            other => {
                Err(format!("unknown sanitize mode {other:?}; choices: off, static, full"))
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SanitizeMode::Off => "off",
            SanitizeMode::Static => "static",
            SanitizeMode::Full => "full",
        }
    }

    /// The environment default: `ZCS_SANITIZE` (off | static | full),
    /// else off.  An unparseable value warns on stderr and falls back to
    /// off.
    pub fn from_env() -> SanitizeMode {
        knob("ZCS_SANITIZE", SanitizeMode::Off, SanitizeMode::parse)
    }

    /// Static verification requested (at or above [`SanitizeMode::Static`]).
    pub fn verify(self) -> bool {
        self >= SanitizeMode::Static
    }

    /// Runtime tripwires and watchdogs requested.
    pub fn dynamic(self) -> bool {
        self >= SanitizeMode::Full
    }
}

/// The process-wide `ZCS_SANITIZE` mode, parsed once.
pub fn env_sanitize() -> SanitizeMode {
    static MODE: OnceLock<SanitizeMode> = OnceLock::new();
    *MODE.get_or_init(SanitizeMode::from_env)
}

/// Parse a positive millisecond count.
pub fn parse_ms(v: &str) -> Result<u64, String> {
    v.parse::<u64>()
        .ok()
        .filter(|&n| n >= 1)
        .ok_or_else(|| format!("{v:?} is not a positive millisecond count"))
}

/// The watchdog stall deadline in milliseconds (`ZCS_STALL_MS`, default
/// 30000): how long the replica all-reduce barrier or the serve
/// dispatcher may sit without progress under [`SanitizeMode::Full`]
/// before the hang is converted into a typed error with a per-thread
/// state dump.  Parsed once per process.
pub fn env_stall_ms() -> u64 {
    static MS: OnceLock<u64> = OnceLock::new();
    *MS.get_or_init(|| knob("ZCS_STALL_MS", 30_000, parse_ms))
}

/// What a [`FaultSpec`] injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// panic in the stepping engine (a replica driver, when replicated)
    Panic,
    /// overwrite a gradient buffer with NaN before the optimizer update
    NanGrad,
    /// truncate the next checkpoint write mid-file (after the CRC is
    /// appended, so the torn file must fail to load)
    TornCkpt,
    /// freeze one replica driver inside its step long enough to trip the
    /// all-reduce stall watchdog (finite even with the watchdog off: the
    /// sleep is bounded, so the step is merely slow)
    Stall,
    /// panic inside a serve worker's eval attempt (1-based attempt count)
    EvalPanic,
    /// stall a serve eval attempt, backing up the admission queue
    Slow,
    /// drop an accepted serve connection before reading its request
    ConnDrop,
}

/// One deterministic injected fault: what, and at which 1-based training
/// step (or serve eval attempt / accepted connection).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    pub step: u64,
}

const FAULT_CHOICES: &str = "panic, nan, torn-ckpt, stall, eval-panic, slow, conn-drop";

impl FaultKind {
    /// The `ZCS_FAULT` spelling of this kind (the inverse of
    /// [`parse_fault_spec`]).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::NanGrad => "nan",
            FaultKind::TornCkpt => "torn-ckpt",
            FaultKind::Stall => "stall",
            FaultKind::EvalPanic => "eval-panic",
            FaultKind::Slow => "slow",
            FaultKind::ConnDrop => "conn-drop",
        }
    }
}

/// Parse one `kind:K` fault spec.
pub fn parse_fault_spec(v: &str) -> Result<FaultSpec, String> {
    let (kind, step) = v
        .split_once(':')
        .ok_or_else(|| format!("{v:?} is not kind:step; choices: {FAULT_CHOICES}"))?;
    let kind = match kind.trim().to_ascii_lowercase().as_str() {
        "panic" => FaultKind::Panic,
        "nan" => FaultKind::NanGrad,
        "torn-ckpt" => FaultKind::TornCkpt,
        "stall" => FaultKind::Stall,
        "eval-panic" => FaultKind::EvalPanic,
        "slow" => FaultKind::Slow,
        "conn-drop" => FaultKind::ConnDrop,
        other => return Err(format!("unknown fault {other:?}; choices: {FAULT_CHOICES}")),
    };
    let step = step
        .trim()
        .parse::<u64>()
        .ok()
        .filter(|&s| s >= 1)
        .ok_or_else(|| format!("{step:?} is not a positive step number"))?;
    Ok(FaultSpec { kind, step })
}

/// Parse a `ZCS_FAULT` value: a comma-separated list of `kind:K` specs,
/// e.g. `eval-panic:3,slow:7`.  One bad spec rejects the whole value, so
/// [`knob`]'s warn-on-typo fallback can never half-apply a list.
pub fn parse_fault(v: &str) -> Result<Vec<FaultSpec>, String> {
    v.split(',').map(|s| parse_fault_spec(s.trim())).collect()
}

/// A set of one-shot faults: each spec fires at most once
/// ([`FaultCell::should_fire`]), and grants its recovery path at most
/// once ([`FaultCell::begin_recovery`]).  The latch is what keeps a whole
/// test suite green under `ZCS_FAULT`: the first trainer to reach the
/// step absorbs the fault, recovers, and every later step runs clean.
#[derive(Debug)]
pub struct FaultCell {
    specs: Vec<FaultSpec>,
    fired: Vec<AtomicBool>,
    recovered: Vec<AtomicBool>,
}

impl FaultCell {
    pub fn new(spec: FaultSpec) -> Self {
        Self::multi(vec![spec])
    }

    pub fn multi(specs: Vec<FaultSpec>) -> Self {
        assert!(!specs.is_empty(), "a fault cell needs at least one spec");
        let fired = specs.iter().map(|_| AtomicBool::new(false)).collect();
        let recovered = specs.iter().map(|_| AtomicBool::new(false)).collect();
        Self { specs, fired, recovered }
    }

    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }

    /// Some spec has not fired yet (recovery snapshots are only worth
    /// taking while this holds).
    pub fn armed(&self) -> bool {
        self.fired.iter().any(|f| !f.load(Ordering::Acquire))
    }

    /// Some spec of `kind` has not fired yet.
    pub fn expects(&self, kind: FaultKind) -> bool {
        self.specs
            .iter()
            .zip(&self.fired)
            .any(|(s, f)| s.kind == kind && !f.load(Ordering::Acquire))
    }

    /// Whether a fault fires here and now: some spec matches `kind` and
    /// `step` and nobody has fired it before (compare-and-swap, so
    /// exactly one call site wins even across threads).
    pub fn should_fire(&self, kind: FaultKind, step: u64) -> bool {
        self.specs.iter().zip(&self.fired).any(|(s, f)| {
            s.kind == kind
                && s.step == step
                && f.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire).is_ok()
        })
    }

    /// Claim the (single) transparent-recovery attempt for a fired fault
    /// of `kind`.  Returns `false` if no such fault fired or every fired
    /// one already had its recovery claimed -- the caller must then
    /// surface the error instead.
    pub fn begin_recovery(&self, kind: FaultKind) -> bool {
        self.specs.iter().enumerate().any(|(i, s)| {
            s.kind == kind
                && self.fired[i].load(Ordering::Acquire)
                && self.recovered[i]
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
        })
    }
}

/// The process-wide `ZCS_FAULT` cell, parsed once: every trainer that
/// does not carry its own cell shares this one, so each configured fault
/// fires exactly once per process.
pub fn env_fault() -> Option<Arc<FaultCell>> {
    static CELL: OnceLock<Option<Arc<FaultCell>>> = OnceLock::new();
    CELL.get_or_init(|| {
        knob("ZCS_FAULT", None, |v| parse_fault(v).map(Some))
            .map(|specs| Arc::new(FaultCell::multi(specs)))
    })
    .clone()
}

/// One row of the `zcs env` table: a knob's effective value and where it
/// came from.
#[derive(Clone, Debug)]
pub struct KnobReport {
    pub name: &'static str,
    /// the parsed, effective value (after warn-on-typo fallback)
    pub value: String,
    /// the built-in default, rendered the same way
    pub default: &'static str,
    /// `default`, `env "raw"`, or `env "raw" (invalid, default used)`
    pub source: String,
    pub help: &'static str,
}

/// Render one knob row: read the variable, parse it with the knob's own
/// parser, and report the effective value plus its source.  Mirrors
/// [`parse_knob`]'s fallback exactly (without re-warning).
fn report_knob<T>(
    name: &'static str,
    default: T,
    default_label: &'static str,
    help: &'static str,
    parse: impl Fn(&str) -> Result<T, String>,
    render: impl Fn(&T) -> String,
) -> KnobReport {
    let raw = std::env::var(name).ok();
    let (value, source) = match raw.as_deref() {
        None => (render(&default), "default".to_string()),
        Some(r) => match parse(r.trim()) {
            Ok(v) => (render(&v), format!("env {r:?}")),
            Err(_) => (render(&default), format!("env {r:?} (invalid, default used)")),
        },
    };
    KnobReport { name, value, default: default_label, source, help }
}

/// Every `ZCS_*` knob with its parsed value, default and source -- the
/// table behind the `zcs env` subcommand.  Each row resolves through the
/// same parser the consuming subsystem uses, so what this prints is what
/// a run would actually do.
pub fn knob_reports() -> Vec<KnobReport> {
    use crate::autodiff::exec::SchedMode;
    use crate::tensor::simd::SimdMode;

    let render_fault = |specs: &Vec<FaultSpec>| -> String {
        if specs.is_empty() {
            "none".to_string()
        } else {
            specs
                .iter()
                .map(|s| format!("{}:{}", s.kind.name(), s.step))
                .collect::<Vec<_>>()
                .join(",")
        }
    };
    vec![
        report_knob(
            "ZCS_THREADS",
            1usize,
            "1",
            "kernel threads per executor pool",
            parse_count,
            |v| v.to_string(),
        ),
        report_knob(
            "ZCS_SCHED",
            SchedMode::Graph,
            "graph",
            "instruction schedule: serial | graph",
            SchedMode::parse,
            |v| v.name().to_string(),
        ),
        report_knob(
            "ZCS_SIMD",
            SimdMode::Auto,
            "auto",
            "kernel lane width: off | 4 | 8 | auto",
            SimdMode::parse,
            |v| v.name().to_string(),
        ),
        report_knob(
            "ZCS_REPLICAS",
            1usize,
            "1",
            "data-parallel replica executors (clamped to the lane count)",
            parse_count,
            |v| v.to_string(),
        ),
        report_knob(
            "ZCS_PROFILE",
            false,
            "off",
            "per-opcode kernel profiling",
            parse_switch,
            |v| if *v { "on" } else { "off" }.to_string(),
        ),
        report_knob(
            "ZCS_FAULT",
            Vec::new(),
            "none",
            "deterministic fault injection: comma-separated kind:step specs",
            parse_fault,
            render_fault,
        ),
        report_knob(
            "ZCS_SANITIZE",
            SanitizeMode::Off,
            "off",
            "correctness layer: off | static | full",
            SanitizeMode::parse,
            |v| v.name().to_string(),
        ),
        report_knob(
            "ZCS_STALL_MS",
            30_000u64,
            "30000",
            "watchdog stall deadline (ms) under sanitize=full",
            parse_ms,
            |v| v.to_string(),
        ),
        report_knob(
            "ZCS_BENCH_QUICK",
            false,
            "off",
            "CI smoke preset for cargo bench (any value = on)",
            |_| Ok(true),
            |v| if *v { "on" } else { "off" }.to_string(),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_yields_the_default_without_parsing() {
        let got = parse_knob("ZCS_TEST", None, 7usize, |_| panic!("must not parse"));
        assert_eq!(got, 7);
    }

    #[test]
    fn set_values_are_trimmed_and_parsed() {
        assert_eq!(parse_knob("ZCS_TEST", Some("  3 "), 1usize, parse_count), 3);
        assert_eq!(parse_knob("ZCS_TEST", Some("on"), false, parse_switch), true);
        assert_eq!(parse_knob("ZCS_TEST", Some("OFF"), true, parse_switch), false);
    }

    #[test]
    fn typos_fall_back_to_the_default() {
        // warns on stderr, never panics, never picks a surprise value
        assert_eq!(parse_knob("ZCS_TEST", Some("fuor"), 4usize, parse_count), 4);
        assert_eq!(parse_knob("ZCS_TEST", Some("0"), 2usize, parse_count), 2);
        assert_eq!(parse_knob("ZCS_TEST", Some("yes"), false, parse_switch), false);
    }

    #[test]
    fn count_and_switch_parsers_cover_their_domains() {
        assert_eq!(parse_count("12"), Ok(12));
        assert!(parse_count("0").is_err());
        assert!(parse_count("-1").is_err());
        assert_eq!(parse_switch(""), Ok(false));
        assert_eq!(parse_switch("TRUE"), Ok(true));
        assert!(parse_switch("maybe").is_err());
    }

    #[test]
    fn fault_specs_parse_and_reject() {
        assert_eq!(
            parse_fault("panic:3"),
            Ok(vec![FaultSpec { kind: FaultKind::Panic, step: 3 }])
        );
        assert_eq!(
            parse_fault("NAN:1"),
            Ok(vec![FaultSpec { kind: FaultKind::NanGrad, step: 1 }])
        );
        assert_eq!(
            parse_fault(" torn-ckpt : 12 "),
            Ok(vec![FaultSpec { kind: FaultKind::TornCkpt, step: 12 }])
        );
        assert_eq!(
            parse_fault("eval-panic:2"),
            Ok(vec![FaultSpec { kind: FaultKind::EvalPanic, step: 2 }])
        );
        assert!(parse_fault("panic").is_err());
        assert!(parse_fault("panic:0").is_err());
        assert!(parse_fault("segv:3").is_err());
        assert!(parse_fault("panic:x").is_err());
    }

    #[test]
    fn fault_lists_parse_every_spec_or_reject_the_whole_value() {
        assert_eq!(
            parse_fault("eval-panic:3,slow:7"),
            Ok(vec![
                FaultSpec { kind: FaultKind::EvalPanic, step: 3 },
                FaultSpec { kind: FaultKind::Slow, step: 7 },
            ])
        );
        assert_eq!(
            parse_fault(" panic:2 , conn-drop:1 , torn-ckpt:4 "),
            Ok(vec![
                FaultSpec { kind: FaultKind::Panic, step: 2 },
                FaultSpec { kind: FaultKind::ConnDrop, step: 1 },
                FaultSpec { kind: FaultKind::TornCkpt, step: 4 },
            ])
        );
        // one bad entry rejects the list -- warn-on-typo then falls back
        // to the default instead of half-applying it
        assert!(parse_fault("panic:2,segv:3").is_err());
        assert!(parse_fault("panic:2,").is_err());
        assert!(parse_fault("").is_err());
        let parse = |v: &str| parse_fault(v).map(Some);
        assert_eq!(parse_knob("ZCS_TEST", Some("panic:2,typo"), None, parse), None);
    }

    #[test]
    fn fault_cell_fires_and_recovers_exactly_once() {
        let cell = FaultCell::new(FaultSpec { kind: FaultKind::Panic, step: 2 });
        assert!(cell.armed());
        assert!(!cell.begin_recovery(FaultKind::Panic), "recovery before firing is refused");
        assert!(!cell.should_fire(FaultKind::Panic, 1), "wrong step");
        assert!(!cell.should_fire(FaultKind::NanGrad, 2), "wrong kind");
        assert!(cell.should_fire(FaultKind::Panic, 2));
        assert!(!cell.armed());
        assert!(!cell.should_fire(FaultKind::Panic, 2), "one shot only");
        assert!(cell.begin_recovery(FaultKind::Panic));
        assert!(!cell.begin_recovery(FaultKind::Panic), "one recovery only");
    }

    #[test]
    fn sanitize_modes_parse_and_order() {
        assert_eq!(SanitizeMode::parse("off"), Ok(SanitizeMode::Off));
        assert_eq!(SanitizeMode::parse("Static"), Ok(SanitizeMode::Static));
        assert_eq!(SanitizeMode::parse("FULL"), Ok(SanitizeMode::Full));
        assert!(SanitizeMode::parse("fullish").is_err());
        // Off < Static < Full is what every gate relies on
        assert!(!SanitizeMode::Off.verify() && !SanitizeMode::Off.dynamic());
        assert!(SanitizeMode::Static.verify() && !SanitizeMode::Static.dynamic());
        assert!(SanitizeMode::Full.verify() && SanitizeMode::Full.dynamic());
        // warn-on-typo fallback applies like every other knob
        let parse = SanitizeMode::parse;
        let off = SanitizeMode::Off;
        assert_eq!(parse_knob("ZCS_TEST", Some("typo"), off, parse), SanitizeMode::Off);
        assert_eq!(parse_knob("ZCS_TEST", Some("full"), off, parse), SanitizeMode::Full);
    }

    #[test]
    fn stall_deadline_and_stall_fault_parse() {
        assert_eq!(parse_ms("250"), Ok(250));
        assert!(parse_ms("0").is_err());
        assert!(parse_ms("fast").is_err());
        assert_eq!(
            parse_fault("stall:3"),
            Ok(vec![FaultSpec { kind: FaultKind::Stall, step: 3 }])
        );
        assert_eq!(FaultKind::Stall.name(), "stall");
    }

    #[test]
    fn fault_kind_names_roundtrip_through_the_parser() {
        for kind in [
            FaultKind::Panic,
            FaultKind::NanGrad,
            FaultKind::TornCkpt,
            FaultKind::Stall,
            FaultKind::EvalPanic,
            FaultKind::Slow,
            FaultKind::ConnDrop,
        ] {
            let spec = parse_fault_spec(&format!("{}:7", kind.name())).unwrap();
            assert_eq!(spec, FaultSpec { kind, step: 7 });
        }
    }

    #[test]
    fn knob_reports_cover_every_documented_knob() {
        let rows = knob_reports();
        let names: Vec<&str> = rows.iter().map(|r| r.name).collect();
        for expect in [
            "ZCS_THREADS",
            "ZCS_SCHED",
            "ZCS_SIMD",
            "ZCS_REPLICAS",
            "ZCS_PROFILE",
            "ZCS_FAULT",
            "ZCS_SANITIZE",
            "ZCS_STALL_MS",
            "ZCS_BENCH_QUICK",
        ] {
            assert!(names.contains(&expect), "missing knob row {expect}");
        }
        for row in &rows {
            assert!(!row.value.is_empty() && !row.source.is_empty(), "{}", row.name);
        }
    }

    #[test]
    fn multi_spec_cells_latch_each_spec_independently() {
        let cell = FaultCell::multi(vec![
            FaultSpec { kind: FaultKind::EvalPanic, step: 1 },
            FaultSpec { kind: FaultKind::EvalPanic, step: 2 },
            FaultSpec { kind: FaultKind::Slow, step: 1 },
        ]);
        assert!(cell.expects(FaultKind::EvalPanic));
        assert!(cell.expects(FaultKind::Slow));
        assert!(!cell.expects(FaultKind::Panic));
        assert!(cell.should_fire(FaultKind::EvalPanic, 1));
        assert!(cell.should_fire(FaultKind::Slow, 1));
        assert!(!cell.should_fire(FaultKind::Slow, 1), "each spec is one-shot");
        assert!(cell.expects(FaultKind::EvalPanic), "step-2 spec still pending");
        assert!(!cell.expects(FaultKind::Slow));
        assert!(cell.should_fire(FaultKind::EvalPanic, 2));
        assert!(!cell.armed());
        assert!(cell.begin_recovery(FaultKind::EvalPanic));
        assert!(cell.begin_recovery(FaultKind::EvalPanic), "second fired spec recovers too");
        assert!(!cell.begin_recovery(FaultKind::EvalPanic), "then the well is dry");
    }
}
