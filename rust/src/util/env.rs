//! One home for `ZCS_*` environment knobs.
//!
//! Every knob (`ZCS_THREADS`, `ZCS_SCHED`, `ZCS_SIMD`, `ZCS_PROFILE`,
//! `ZCS_REPLICAS`, `ZCS_FAULT`) resolves through [`knob`], which gives
//! them all the warn-on-typo fallback `ZCS_SIMD` pioneered: an unset
//! variable yields the default silently, an unparseable value warns once
//! on stderr and *then* yields the default -- a typo can never silently
//! select the behaviour the user tried to exclude, and never aborts a
//! run either.
//!
//! [`parse_knob`] is the pure core (no process environment touched), so
//! the policy is unit-testable without mutating env vars from a threaded
//! test binary.
//!
//! `ZCS_FAULT` is the deterministic fault injector behind the
//! crash-safety layer: `panic:K` makes the stepping engine panic at step
//! `K`, `nan:K` poisons a gradient buffer with NaN at step `K`, and
//! `torn-ckpt:K` truncates the checkpoint written at step `K` mid-file.
//! Each [`FaultCell`] fires **exactly once** (process-wide for the
//! environment cell), so the recovery path runs under fault and the rest
//! of the process proceeds normally -- which is what lets CI run the
//! whole test suite with injection enabled.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

/// Resolve one knob from an already-read raw value: `None` (unset) gives
/// the default silently; `Some` is trimmed and parsed, and a parse error
/// warns on stderr and falls back to the default.
pub fn parse_knob<T>(
    name: &str,
    raw: Option<&str>,
    default: T,
    parse: impl FnOnce(&str) -> Result<T, String>,
) -> T {
    match raw {
        Some(v) => parse(v.trim()).unwrap_or_else(|e| {
            eprintln!("warning: {name} ignored: {e}");
            default
        }),
        None => default,
    }
}

/// Read `name` from the process environment and resolve it via
/// [`parse_knob`].
pub fn knob<T>(name: &str, default: T, parse: impl FnOnce(&str) -> Result<T, String>) -> T {
    let raw = std::env::var(name).ok();
    parse_knob(name, raw.as_deref(), default, parse)
}

/// Parse a positive count (`>= 1`), for thread and replica budgets.
pub fn parse_count(v: &str) -> Result<usize, String> {
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(format!("{v:?} is not a positive integer")),
    }
}

/// Parse an on/off switch: `1 | true | on` and `0 | false | off | ""`
/// (case-insensitive).
pub fn parse_switch(v: &str) -> Result<bool, String> {
    match v.to_ascii_lowercase().as_str() {
        "1" | "true" | "on" => Ok(true),
        "" | "0" | "false" | "off" => Ok(false),
        other => Err(format!("{other:?} is not a switch; choices: 0, 1, true, false, on, off")),
    }
}

/// The `ZCS_REPLICAS` default: data-parallel replica executors per
/// trainer (clamped to the canonical lane count downstream), else 1.
pub fn default_replicas() -> usize {
    knob("ZCS_REPLICAS", 1, parse_count)
}

/// What a [`FaultSpec`] injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// panic in the stepping engine (a replica driver, when replicated)
    Panic,
    /// overwrite a gradient buffer with NaN before the optimizer update
    NanGrad,
    /// truncate the next checkpoint write mid-file (after the CRC is
    /// appended, so the torn file must fail to load)
    TornCkpt,
}

/// One deterministic injected fault: what, and at which 1-based training
/// step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    pub step: u64,
}

/// Parse a `ZCS_FAULT` value: `panic:K`, `nan:K`, or `torn-ckpt:K`.
pub fn parse_fault(v: &str) -> Result<FaultSpec, String> {
    let (kind, step) = v
        .split_once(':')
        .ok_or_else(|| format!("{v:?} is not kind:step; choices: panic, nan, torn-ckpt"))?;
    let kind = match kind.trim().to_ascii_lowercase().as_str() {
        "panic" => FaultKind::Panic,
        "nan" => FaultKind::NanGrad,
        "torn-ckpt" => FaultKind::TornCkpt,
        other => return Err(format!("unknown fault {other:?}; choices: panic, nan, torn-ckpt")),
    };
    let step = step
        .trim()
        .parse::<u64>()
        .ok()
        .filter(|&s| s >= 1)
        .ok_or_else(|| format!("{step:?} is not a positive step number"))?;
    Ok(FaultSpec { kind, step })
}

/// A one-shot fault: fires at most once ([`FaultCell::should_fire`]),
/// and grants the recovery path at most once ([`FaultCell::begin_recovery`]).
/// The latch is what keeps a whole test suite green under `ZCS_FAULT`:
/// the first trainer to reach the step absorbs the fault, recovers, and
/// every later step runs clean.
#[derive(Debug)]
pub struct FaultCell {
    spec: FaultSpec,
    fired: AtomicBool,
    recovered: AtomicBool,
}

impl FaultCell {
    pub fn new(spec: FaultSpec) -> Self {
        Self { spec, fired: AtomicBool::new(false), recovered: AtomicBool::new(false) }
    }

    pub fn spec(&self) -> FaultSpec {
        self.spec
    }

    /// The fault has not fired yet (recovery snapshots are only worth
    /// taking while this holds).
    pub fn armed(&self) -> bool {
        !self.fired.load(Ordering::Acquire)
    }

    /// Whether the fault fires here and now: `kind` and `step` match and
    /// nobody has fired it before (compare-and-swap, so exactly one call
    /// site wins even across threads).
    pub fn should_fire(&self, kind: FaultKind, step: u64) -> bool {
        self.spec.kind == kind
            && self.spec.step == step
            && self.fired.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire).is_ok()
    }

    /// Claim the (single) transparent-recovery attempt for a fired fault.
    /// Returns `false` if the fault never fired or recovery was already
    /// claimed -- the caller must then surface the error instead.
    pub fn begin_recovery(&self) -> bool {
        self.fired.load(Ordering::Acquire)
            && self
                .recovered
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
    }
}

/// The process-wide `ZCS_FAULT` cell, parsed once: every trainer that
/// does not carry its own cell shares this one, so the configured fault
/// fires exactly once per process.
pub fn env_fault() -> Option<Arc<FaultCell>> {
    static CELL: OnceLock<Option<Arc<FaultCell>>> = OnceLock::new();
    CELL.get_or_init(|| {
        knob("ZCS_FAULT", None, |v| parse_fault(v).map(Some))
            .map(|spec| Arc::new(FaultCell::new(spec)))
    })
    .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_yields_the_default_without_parsing() {
        let got = parse_knob("ZCS_TEST", None, 7usize, |_| panic!("must not parse"));
        assert_eq!(got, 7);
    }

    #[test]
    fn set_values_are_trimmed_and_parsed() {
        assert_eq!(parse_knob("ZCS_TEST", Some("  3 "), 1usize, parse_count), 3);
        assert_eq!(parse_knob("ZCS_TEST", Some("on"), false, parse_switch), true);
        assert_eq!(parse_knob("ZCS_TEST", Some("OFF"), true, parse_switch), false);
    }

    #[test]
    fn typos_fall_back_to_the_default() {
        // warns on stderr, never panics, never picks a surprise value
        assert_eq!(parse_knob("ZCS_TEST", Some("fuor"), 4usize, parse_count), 4);
        assert_eq!(parse_knob("ZCS_TEST", Some("0"), 2usize, parse_count), 2);
        assert_eq!(parse_knob("ZCS_TEST", Some("yes"), false, parse_switch), false);
    }

    #[test]
    fn count_and_switch_parsers_cover_their_domains() {
        assert_eq!(parse_count("12"), Ok(12));
        assert!(parse_count("0").is_err());
        assert!(parse_count("-1").is_err());
        assert_eq!(parse_switch(""), Ok(false));
        assert_eq!(parse_switch("TRUE"), Ok(true));
        assert!(parse_switch("maybe").is_err());
    }

    #[test]
    fn fault_specs_parse_and_reject() {
        assert_eq!(parse_fault("panic:3"), Ok(FaultSpec { kind: FaultKind::Panic, step: 3 }));
        assert_eq!(parse_fault("NAN:1"), Ok(FaultSpec { kind: FaultKind::NanGrad, step: 1 }));
        assert_eq!(
            parse_fault(" torn-ckpt : 12 "),
            Ok(FaultSpec { kind: FaultKind::TornCkpt, step: 12 })
        );
        assert!(parse_fault("panic").is_err());
        assert!(parse_fault("panic:0").is_err());
        assert!(parse_fault("segv:3").is_err());
        assert!(parse_fault("panic:x").is_err());
    }

    #[test]
    fn fault_cell_fires_and_recovers_exactly_once() {
        let cell = FaultCell::new(FaultSpec { kind: FaultKind::Panic, step: 2 });
        assert!(cell.armed());
        assert!(!cell.begin_recovery(), "recovery before firing is refused");
        assert!(!cell.should_fire(FaultKind::Panic, 1), "wrong step");
        assert!(!cell.should_fire(FaultKind::NanGrad, 2), "wrong kind");
        assert!(cell.should_fire(FaultKind::Panic, 2));
        assert!(!cell.armed());
        assert!(!cell.should_fire(FaultKind::Panic, 2), "one shot only");
        assert!(cell.begin_recovery());
        assert!(!cell.begin_recovery(), "one recovery only");
    }
}
