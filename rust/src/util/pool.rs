//! Pure-std scoped worker pool for deterministic data-parallel kernels.
//!
//! The build is offline (no rayon/crossbeam), so the pool is built from
//! `std::thread` + `Mutex`/`Condvar`/`AtomicUsize` only.  Design goals, in
//! order:
//!
//! 1. **Bit-exactness.**  Work is split into *data-disjoint* tasks (e.g.
//!    contiguous row blocks of a matmul output) and every task performs the
//!    same scalar operation sequence the serial kernel would -- which thread
//!    claims which task never changes a single bit of the result.  The
//!    differential tests in `rust/tests/fusion_pool.rs` pin
//!    pooled == serial to `==`.
//! 2. **Persistence.**  Workers are spawned once and parked on a condvar
//!    between jobs; submitting a job is a mutex lock + notify, not a thread
//!    spawn, so the pool is usable from kernels that run thousands of times
//!    per training step.
//! 3. **Scoped borrows.**  [`Pool::run`] accepts a non-`'static` closure.
//!    The borrow is erased to hand it to the persistent workers and
//!    re-validated by construction: `run` does not return until every
//!    claimed task has finished, and a late-waking worker can only observe
//!    the job after all tasks are claimed, in which case it executes
//!    nothing (see the `SAFETY` comment in [`Pool::run`]).
//!
//! A `Pool` with one thread (the default) spawns no workers and runs
//! everything inline -- `Pool::serial()` is free to construct, so serial
//! kernel wrappers can share the pooled code path.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// First panic payload captured from a task (worker or submitter side).
type PanicSlot = Arc<Mutex<Option<Box<dyn std::any::Any + Send>>>>;

/// Number of threads to use when the caller asks for "auto": the
/// `ZCS_THREADS` environment variable, else 1 (serial).
pub fn default_threads() -> usize {
    std::env::var("ZCS_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// One published job: a type-erased task closure plus the claim/finish
/// counters.  `f` is only *called* for task indices below `n_tasks`, all of
/// which are claimed (and completed) before [`Pool::run`] returns, so the
/// erased borrow never escapes the submitting call.
#[derive(Clone)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    next: Arc<AtomicUsize>,
    done: Arc<AtomicUsize>,
    /// first panic from any task; re-raised by the submitter after all
    /// tasks have finished (so the erased borrow is dead before unwinding)
    panic: PanicSlot,
    n_tasks: usize,
}

/// Claim-and-execute loop shared by workers and the submitter.  Panics in
/// `f` are captured (first one wins) and `done` is incremented regardless,
/// so a panicking task can never hang [`Pool::run`].
fn drain_tasks(
    f: &(dyn Fn(usize) + Sync),
    next: &AtomicUsize,
    done: &AtomicUsize,
    panic_slot: &PanicSlot,
    n_tasks: usize,
) {
    loop {
        let t = next.fetch_add(1, Ordering::Relaxed);
        if t >= n_tasks {
            break;
        }
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(t))) {
            let mut slot = panic_slot.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        done.fetch_add(1, Ordering::Release);
    }
}

struct Control {
    /// bumped once per submitted job so a worker never re-enters a job it
    /// already drained
    epoch: u64,
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    ctl: Mutex<Control>,
    /// workers park here between jobs
    work_cv: Condvar,
    /// the submitter parks here waiting for stragglers
    done_cv: Condvar,
}

/// Persistent worker pool; see the module docs.
pub struct Pool {
    shared: Option<Arc<Shared>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// A pool that runs tasks on `threads` threads total (the submitting
    /// thread participates, so `threads - 1` workers are spawned).
    /// `threads <= 1` builds a serial pool with no worker threads.
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        if threads == 1 {
            return Pool { shared: None, workers: Vec::new(), threads: 1 };
        }
        let shared = Arc::new(Shared {
            ctl: Mutex::new(Control { epoch: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (0..threads - 1)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Pool { shared: Some(shared), workers, threads }
    }

    /// A no-thread pool that runs everything inline (free to construct).
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), ..., f(n_tasks - 1)`, distributing task indices
    /// over the pool (the calling thread participates).  Tasks must be
    /// data-disjoint; every call to `f` has returned when `run` returns.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        let Some(shared) = self.shared.as_ref() else {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        };
        if n_tasks <= 1 {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        }
        // SAFETY: the borrow's lifetime is erased to 'static so it can
        // reach the persistent workers, but it is only dereferenced for
        // task indices claimed from `next` while they are < n_tasks.  We
        // block below until `done == n_tasks`, i.e. until every claimed
        // task has *finished*; a worker that wakes after that point claims
        // an index >= n_tasks and never touches `f`.  Hence the borrow is
        // never used after `run` returns.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let next = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));
        let panic_slot: PanicSlot = Arc::new(Mutex::new(None));
        {
            let mut ctl = shared.ctl.lock().unwrap();
            ctl.epoch += 1;
            ctl.job = Some(Job {
                f: f_static,
                next: Arc::clone(&next),
                done: Arc::clone(&done),
                panic: Arc::clone(&panic_slot),
                n_tasks,
            });
            shared.work_cv.notify_all();
        }
        // participate (panics captured, never unwound past live workers)
        drain_tasks(f, &next, &done, &panic_slot, n_tasks);
        // wait for stragglers, then retire the job
        {
            let mut ctl = shared.ctl.lock().unwrap();
            while done.load(Ordering::Acquire) < n_tasks {
                ctl = shared.done_cv.wait(ctl).unwrap();
            }
            ctl.job = None;
        }
        // every task has finished and no worker holds the erased borrow
        // any more: now a captured panic can safely unwind the submitter
        if let Some(payload) = panic_slot.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }

    /// Split `out` (a `rows x row_len` row-major buffer) into contiguous
    /// row blocks of at least `min_rows` rows and run
    /// `f(row_range, block)` over them in parallel.  Blocks are disjoint,
    /// the partition depends only on `rows` and the pool size, and `f`
    /// must fully define the block it is given.
    pub fn par_rows(
        &self,
        rows: usize,
        row_len: usize,
        out: &mut [f64],
        min_rows: usize,
        f: impl Fn(std::ops::Range<usize>, &mut [f64]) + Sync,
    ) {
        assert_eq!(out.len(), rows * row_len, "par_rows buffer size");
        let min_rows = min_rows.max(1);
        let n_tasks = if rows == 0 { 0 } else { self.threads.min(rows.div_ceil(min_rows)).max(1) };
        if n_tasks <= 1 {
            if rows > 0 {
                f(0..rows, out);
            }
            return;
        }
        let base = SyncPtr(out.as_mut_ptr());
        self.run(n_tasks, &|t: usize| {
            let lo = rows * t / n_tasks;
            let hi = rows * (t + 1) / n_tasks;
            if lo >= hi {
                return;
            }
            // SAFETY: [lo, hi) blocks are disjoint across tasks and stay
            // within the `rows * row_len` buffer `base` points into, which
            // outlives `run` (it borrows `out`).  `base.get()` (a &self
            // method) makes the closure capture the Sync wrapper, not the
            // raw pointer field.
            let block = unsafe {
                std::slice::from_raw_parts_mut(base.get().add(lo * row_len), (hi - lo) * row_len)
            };
            f(lo..hi, block);
        });
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::serial()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Some(shared) = self.shared.take() {
            {
                let mut ctl = shared.ctl.lock().unwrap();
                ctl.shutdown = true;
                shared.work_cv.notify_all();
            }
            for h in self.workers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Raw base pointer made shareable so the task closure can slice disjoint
/// blocks out of one `&mut [f64]`.  Access goes through [`SyncPtr::get`]
/// so closures capture the wrapper (Sync) rather than the raw pointer
/// field (not Sync) under edition-2021 disjoint capture.
struct SyncPtr(*mut f64);

impl SyncPtr {
    fn get(&self) -> *mut f64 {
        self.0
    }
}

unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut ctl = shared.ctl.lock().unwrap();
            loop {
                if ctl.shutdown {
                    return;
                }
                if ctl.job.is_some() && ctl.epoch != last_epoch {
                    last_epoch = ctl.epoch;
                    break ctl.job.clone().unwrap();
                }
                ctl = shared.work_cv.wait(ctl).unwrap();
            }
        };
        drain_tasks(job.f, &job.next, &job.done, &job.panic, job.n_tasks);
        // lock before notifying so the submitter is either already waiting
        // or will observe the final count when it re-checks
        let _ctl = shared.ctl.lock().unwrap();
        shared.done_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn serial_pool_runs_inline() {
        let pool = Pool::serial();
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(5, &|t| {
            hits.fetch_add(t + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1 + 2 + 3 + 4 + 5);
    }

    #[test]
    fn every_task_runs_exactly_once() {
        let pool = Pool::new(4);
        let n = 1000;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.run(n, &|t| {
            counts[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = Pool::new(3);
        for round in 0..50usize {
            let sum = AtomicUsize::new(0);
            pool.run(8, &|t| {
                sum.fetch_add(t + round, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 28 + 8 * round);
        }
    }

    #[test]
    fn par_rows_covers_the_buffer_disjointly() {
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let (rows, row_len) = (37, 5);
            let mut out = vec![0.0f64; rows * row_len];
            pool.par_rows(rows, row_len, &mut out, 1, |range, block| {
                assert_eq!(block.len(), (range.end - range.start) * row_len);
                for (off, v) in block.iter_mut().enumerate() {
                    *v += (range.start * row_len + off) as f64;
                }
            });
            // every element written exactly once with its own index
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as f64, "thread count {threads}");
            }
        }
    }

    #[test]
    fn par_rows_respects_min_rows() {
        let pool = Pool::new(8);
        let mut out = vec![0.0f64; 6];
        // 6 rows, min 4 per task -> at most 2 tasks; just check coverage
        pool.par_rows(6, 1, &mut out, 4, |range, block| {
            for (off, v) in block.iter_mut().enumerate() {
                *v = (range.start + off) as f64 + 1.0;
            }
        });
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn empty_and_single_task_jobs() {
        let pool = Pool::new(2);
        pool.run(0, &|_| panic!("no tasks should run"));
        let hits = AtomicUsize::new(0);
        pool.run(1, &|t| {
            assert_eq!(t, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        let mut out: Vec<f64> = Vec::new();
        pool.par_rows(0, 3, &mut out, 1, |_, _| panic!("no rows"));
    }

    #[test]
    fn task_panics_propagate_without_hanging() {
        let pool = Pool::new(3);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, &|t| {
                if t == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(outcome.is_err(), "panic should reach the submitter");
        // the pool survives and the next job runs normally
        let hits = AtomicUsize::new(0);
        pool.run(4, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn default_threads_reads_env_or_one() {
        // can't mutate the environment safely in a test binary that may run
        // threaded; just pin the parse contract on the current value
        let n = default_threads();
        assert!(n >= 1);
    }
}
