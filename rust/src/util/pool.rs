//! Pure-std scoped worker pool for deterministic data-parallel kernels.
//!
//! The build is offline (no rayon/crossbeam), so the pool is built from
//! `std::thread` + `Mutex`/`Condvar`/`AtomicUsize` only.  Design goals, in
//! order:
//!
//! 1. **Bit-exactness.**  Work is split into *data-disjoint* tasks (e.g.
//!    contiguous row blocks of a matmul output) and every task performs the
//!    same scalar operation sequence the serial kernel would -- which thread
//!    claims which task never changes a single bit of the result.  The
//!    differential tests in `rust/tests/fusion_pool.rs` pin
//!    pooled == serial to `==`.
//! 2. **Persistence.**  Workers are spawned once and parked on a condvar
//!    between jobs; submitting a job is a mutex lock + notify, not a thread
//!    spawn, so the pool is usable from kernels that run thousands of times
//!    per training step.
//! 3. **Scoped borrows.**  [`Pool::run`] accepts a non-`'static` closure.
//!    The borrow is erased to hand it to the persistent workers and
//!    re-validated by construction: `run` does not return until every
//!    claimed task has finished, and a late-waking worker can only observe
//!    the job after all tasks are claimed, in which case it executes
//!    nothing (see the `SAFETY` comment in [`Pool::run`]).
//!
//! The pool has two dispatch modes:
//!
//! * **Fork-join** ([`Pool::run`] / [`Pool::par_rows`]): one data-parallel
//!   job at a time, split into disjoint tasks, with an implicit barrier when
//!   `run` returns.  This is what individual kernels use.
//! * **Ready-queue** ([`Pool::run_graph`]): a whole dependency DAG of nodes
//!   (compiled-program instructions) is handed over at once; workers
//!   atomically claim nodes whose predecessors have all retired, execute
//!   them inline, and unlock their successors -- independent nodes overlap
//!   instead of paying a barrier per node.  A node that is itself a heavy
//!   row-split kernel still calls [`Pool::run`], which detects the graph
//!   context and publishes its row blocks to a *help list* that idle graph
//!   workers drain, so large matmuls keep their intra-kernel parallelism.
//!
//! A `Pool` with one thread (the default) spawns no workers and runs
//! everything inline -- `Pool::serial()` is free to construct, so serial
//! kernel wrappers can share the pooled code path.
//!
//! A process is **not** limited to one pool: the data-parallel replica
//! layer ([`crate::coordinator::replica`]) pins one independent `Pool`
//! per replica executor (each a disjoint worker group carved from the
//! total `ZCS_THREADS` budget), and the pools never share jobs -- each
//! replica's kernels dispatch only on its own workers, which keeps every
//! replica's task split, and therefore its bits, identical to a
//! single-replica run of the same lane block.

use std::cell::RefCell;
use std::collections::BinaryHeap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Shared per-task minimum grain sizes for every data-parallel dispatch --
/// the row-split kernels in [`crate::tensor::kernels`] and the ready-queue
/// help protocol all size their tasks from here, so "is this worth another
/// thread?" is answered once, not per call site.  Unit tests shrink the
/// minimums to a few elements so the pooled code paths genuinely cross
/// threads even on tiny tensors (the production values would run them
/// inline and the threaded == serial differential tests would prove
/// nothing).
pub mod grain {
    /// Minimum multiply-adds per matmul task; below this a row block is
    /// not worth shipping to another thread.
    #[cfg(not(test))]
    pub const MATMUL_FLOPS_PER_TASK: usize = 16 * 1024;
    #[cfg(test)]
    pub const MATMUL_FLOPS_PER_TASK: usize = 8;
    /// Minimum elements per task for elementwise kernels and reductions.
    #[cfg(not(test))]
    pub const ELEMWISE_PER_TASK: usize = 4 * 1024;
    #[cfg(test)]
    pub const ELEMWISE_PER_TASK: usize = 2;

    /// Minimum output rows per task for an `(m, k) @ (k, n)`-shaped matmul.
    pub fn matmul_rows(k: usize, n: usize) -> usize {
        (MATMUL_FLOPS_PER_TASK / (k * n).max(1)).max(1)
    }

    /// Minimum rows per task for an elementwise pass / reduction whose
    /// rows hold `row_len` elements each.
    pub fn elemwise_rows(row_len: usize) -> usize {
        (ELEMWISE_PER_TASK / row_len.max(1)).max(1)
    }

    /// SIMD-aware [`elemwise_rows`]: a `width`-lane kernel retires
    /// `width` elements per dispatch, so a task must be `width`x larger
    /// to amortize the same fork-join overhead.  `width == 1` is exactly
    /// the scalar policy.
    pub fn elemwise_rows_simd(row_len: usize, width: usize) -> usize {
        elemwise_rows(row_len).saturating_mul(width.max(1))
    }

    /// SIMD-aware [`matmul_rows`]: same scaling rationale as
    /// [`elemwise_rows_simd`].
    pub fn matmul_rows_simd(k: usize, n: usize, width: usize) -> usize {
        matmul_rows(k, n).saturating_mul(width.max(1))
    }
}

/// First panic payload captured from a task (worker or submitter side).
type PanicSlot = Arc<Mutex<Option<Box<dyn std::any::Any + Send>>>>;

/// Number of threads to use when the caller asks for "auto": the
/// `ZCS_THREADS` environment variable, else 1 (serial).  This is the
/// *total* budget; a multi-replica trainer splits it evenly across its
/// per-replica pools ([`crate::coordinator::replica`]).
pub fn default_threads() -> usize {
    crate::util::env::knob("ZCS_THREADS", 1, crate::util::env::parse_count)
}

/// One published job: a type-erased task closure plus the claim/finish
/// counters.  `f` is only *called* for task indices below `n_tasks`, all of
/// which are claimed (and completed) before [`Pool::run`] returns, so the
/// erased borrow never escapes the submitting call.
#[derive(Clone)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    next: Arc<AtomicUsize>,
    done: Arc<AtomicUsize>,
    /// first panic from any task; re-raised by the submitter after all
    /// tasks have finished (so the erased borrow is dead before unwinding)
    panic: PanicSlot,
    n_tasks: usize,
}

/// Claim-and-execute loop shared by workers and the submitter.  Panics in
/// `f` are captured (first one wins) and `done` is incremented regardless,
/// so a panicking task can never hang [`Pool::run`].
fn drain_tasks(
    f: &(dyn Fn(usize) + Sync),
    next: &AtomicUsize,
    done: &AtomicUsize,
    panic_slot: &PanicSlot,
    n_tasks: usize,
) {
    loop {
        let t = next.fetch_add(1, Ordering::Relaxed);
        if t >= n_tasks {
            break;
        }
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(t))) {
            let mut slot = panic_slot.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        done.fetch_add(1, Ordering::Release);
    }
}

// ---------------------------------------------------------------------------
// Ready-queue (graph) mode
// ---------------------------------------------------------------------------

/// Borrowed description of a dependency DAG for [`Pool::run_graph`]:
/// per-node predecessor counts, CSR successor lists and a static claim
/// priority (higher first; typically critical-path length).
#[derive(Clone, Copy)]
pub struct GraphSpec<'a> {
    /// predecessor count per node
    pub n_preds: &'a [u32],
    /// flattened successor lists, indexed by [`GraphSpec::succ_offsets`]
    pub succs: &'a [u32],
    /// `succs[succ_offsets[i]..succ_offsets[i + 1]]` are node `i`'s
    /// successors; length `n_nodes + 1`
    pub succ_offsets: &'a [u32],
    /// static scheduling priority per node (higher claims first)
    pub priority: &'a [u64],
}

impl GraphSpec<'_> {
    pub fn n_nodes(&self) -> usize {
        self.n_preds.len()
    }

    fn succs_of(&self, i: u32) -> &[u32] {
        let lo = self.succ_offsets[i as usize] as usize;
        let hi = self.succ_offsets[i as usize + 1] as usize;
        &self.succs[lo..hi]
    }
}

/// Shared state of one in-flight [`Pool::run_graph`] call.
struct GraphCtx {
    q: Mutex<GraphQueue>,
    /// graph workers park here when no node is ready and no help task is
    /// claimable; notified on node pushes, help publishes and completion
    cv: Condvar,
    /// outstanding predecessor count per node; a node is claimable when
    /// its counter hits zero
    pending: Vec<AtomicU32>,
    /// nodes fully executed so far; `retired == n` terminates the run
    retired: AtomicUsize,
    n: usize,
    /// set when a node panicked: workers drain out instead of hanging
    abort: AtomicBool,
}

struct GraphQueue {
    /// ready nodes, keyed by priority (max-heap)
    heap: BinaryHeap<(u64, u32)>,
    /// row-split jobs published by heavy kernels running on graph workers
    /// (see [`GraphCtx::run_nested`]); idle workers claim tasks from here
    help: Vec<Job>,
}

thread_local! {
    /// The graph run this thread is currently a worker of, if any --
    /// consulted by [`Pool::run`] to route nested row-split jobs to the
    /// graph's help list instead of the (busy) parked-worker protocol.
    static GRAPH_CTX: RefCell<Option<Arc<GraphCtx>>> = const { RefCell::new(None) };
}

/// Clears the thread-local graph context on scope exit (including panics).
struct GraphCtxGuard;

impl GraphCtxGuard {
    fn set(ctx: Arc<GraphCtx>) -> GraphCtxGuard {
        GRAPH_CTX.with(|g| *g.borrow_mut() = Some(ctx));
        GraphCtxGuard
    }
}

impl Drop for GraphCtxGuard {
    fn drop(&mut self) {
        let _ = GRAPH_CTX.try_with(|g| *g.borrow_mut() = None);
    }
}

impl GraphCtx {
    /// A nested fork-join job submitted by a node running on a graph
    /// worker: publish the tasks to the help list (idle graph workers
    /// claim them), participate, and spin out the stragglers.  The erased
    /// borrow is dead before return for the same reason as in
    /// [`Pool::run`]: every claimed task has finished, and late observers
    /// claim indices `>= n_tasks`.
    fn run_nested(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        // SAFETY: as in `Pool::run` -- the borrow is only dereferenced for
        // claimed task indices, all of which finish before this returns.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let next = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));
        let panic_slot: PanicSlot = Arc::new(Mutex::new(None));
        {
            let mut q = self.q.lock().unwrap();
            q.help.push(Job {
                f: f_static,
                next: Arc::clone(&next),
                done: Arc::clone(&done),
                panic: Arc::clone(&panic_slot),
                n_tasks,
            });
            self.cv.notify_all();
        }
        drain_tasks(f, &next, &done, &panic_slot, n_tasks);
        // stragglers hold at most one row block each: spin briefly
        let mut spins = 0u32;
        while done.load(Ordering::Acquire) < n_tasks {
            spins = spins.wrapping_add(1);
            if spins % 64 == 0 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
        {
            let mut q = self.q.lock().unwrap();
            q.help.retain(|j| !Arc::ptr_eq(&j.next, &next));
        }
        if let Some(payload) = panic_slot.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

/// One graph worker: claim ready nodes (preferring the just-unlocked
/// highest-priority successor, which skips the queue entirely for chain
/// sections), execute them, retire them, and help heavy kernels while
/// idle.
fn graph_worker_loop(
    ctx: &GraphCtx,
    spec: &GraphSpec<'_>,
    node: &(dyn Fn(u32, usize) + Sync),
    w: usize,
) {
    let mut extra: Vec<u32> = Vec::new();
    let mut next: Option<u32> = None;
    'outer: loop {
        let i = match next.take() {
            Some(i) => i,
            None => {
                let mut q = ctx.q.lock().unwrap();
                loop {
                    if ctx.abort.load(Ordering::Relaxed)
                        || ctx.retired.load(Ordering::Acquire) >= ctx.n
                    {
                        break 'outer;
                    }
                    if let Some((_, i)) = q.heap.pop() {
                        break i;
                    }
                    let claimable = q
                        .help
                        .iter()
                        .find(|j| j.next.load(Ordering::Relaxed) < j.n_tasks)
                        .cloned();
                    if let Some(job) = claimable {
                        drop(q);
                        drain_tasks(job.f, &job.next, &job.done, &job.panic, job.n_tasks);
                        q = ctx.q.lock().unwrap();
                        continue;
                    }
                    q = ctx.cv.wait(q).unwrap();
                }
            }
        };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| node(i, w))) {
            // wake everyone so the run drains out, then let the worker-task
            // machinery capture the payload and re-raise it on the submitter
            ctx.abort.store(true, Ordering::Relaxed);
            let _q = ctx.q.lock().unwrap();
            ctx.cv.notify_all();
            drop(_q);
            resume_unwind(payload);
        }
        // retire: unlock successors, keeping the best one for ourselves
        for &s in spec.succs_of(i) {
            if ctx.pending[s as usize].fetch_sub(1, Ordering::AcqRel) == 1 {
                match next {
                    None => next = Some(s),
                    Some(cur) if spec.priority[s as usize] > spec.priority[cur as usize] => {
                        extra.push(cur);
                        next = Some(s);
                    }
                    Some(_) => extra.push(s),
                }
            }
        }
        let retired_now = ctx.retired.fetch_add(1, Ordering::AcqRel) + 1;
        if !extra.is_empty() || retired_now == ctx.n {
            let mut q = ctx.q.lock().unwrap();
            for &e in &extra {
                q.heap.push((spec.priority[e as usize], e));
            }
            extra.clear();
            ctx.cv.notify_all();
        }
    }
}

struct Control {
    /// bumped once per submitted job so a worker never re-enters a job it
    /// already drained
    epoch: u64,
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    ctl: Mutex<Control>,
    /// workers park here between jobs
    work_cv: Condvar,
    /// the submitter parks here waiting for stragglers
    done_cv: Condvar,
}

/// Persistent worker pool; see the module docs.
pub struct Pool {
    shared: Option<Arc<Shared>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// A pool that runs tasks on `threads` threads total (the submitting
    /// thread participates, so `threads - 1` workers are spawned).
    /// `threads <= 1` builds a serial pool with no worker threads.
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        if threads == 1 {
            return Pool { shared: None, workers: Vec::new(), threads: 1 };
        }
        let shared = Arc::new(Shared {
            ctl: Mutex::new(Control { epoch: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (0..threads - 1)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Pool { shared: Some(shared), workers, threads }
    }

    /// A no-thread pool that runs everything inline (free to construct).
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), ..., f(n_tasks - 1)`, distributing task indices
    /// over the pool (the calling thread participates).  Tasks must be
    /// data-disjoint; every call to `f` has returned when `run` returns.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        let Some(shared) = self.shared.as_ref() else {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        };
        if n_tasks <= 1 {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        }
        // a nested job from inside a graph worker: the parked-worker
        // protocol is busy running the graph loop, so publish the tasks to
        // the graph's help list where idle workers claim them
        if let Some(ctx) = GRAPH_CTX.with(|g| g.borrow().clone()) {
            ctx.run_nested(n_tasks, f);
            return;
        }
        // SAFETY: the borrow's lifetime is erased to 'static so it can
        // reach the persistent workers, but it is only dereferenced for
        // task indices claimed from `next` while they are < n_tasks.  We
        // block below until `done == n_tasks`, i.e. until every claimed
        // task has *finished*; a worker that wakes after that point claims
        // an index >= n_tasks and never touches `f`.  Hence the borrow is
        // never used after `run` returns.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let next = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));
        let panic_slot: PanicSlot = Arc::new(Mutex::new(None));
        {
            let mut ctl = shared.ctl.lock().unwrap();
            ctl.epoch += 1;
            ctl.job = Some(Job {
                f: f_static,
                next: Arc::clone(&next),
                done: Arc::clone(&done),
                panic: Arc::clone(&panic_slot),
                n_tasks,
            });
            shared.work_cv.notify_all();
        }
        // participate (panics captured, never unwound past live workers)
        drain_tasks(f, &next, &done, &panic_slot, n_tasks);
        // wait for stragglers, then retire the job
        {
            let mut ctl = shared.ctl.lock().unwrap();
            while done.load(Ordering::Acquire) < n_tasks {
                ctl = shared.done_cv.wait(ctl).unwrap();
            }
            ctl.job = None;
        }
        // every task has finished and no worker holds the erased borrow
        // any more: now a captured panic can safely unwind the submitter
        if let Some(payload) = panic_slot.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }

    /// Execute a dependency DAG of nodes over the pool in ready-queue
    /// mode: `node(i, worker)` is called exactly once per node `i`, only
    /// after all of `i`'s predecessors have returned, with `worker` in
    /// `0..threads()` identifying the claiming worker (distinct
    /// concurrently-running nodes always see distinct worker indices).
    /// Independent nodes run concurrently with no barrier between them;
    /// claim order follows `spec.priority` (highest first) but is
    /// otherwise unspecified -- callers must make any interleaving of
    /// independent nodes valid (the compiler's hazard edges do exactly
    /// that for program instructions).
    ///
    /// A node may call [`Pool::run`] / [`Pool::par_rows`] (heavy kernels
    /// row-splitting); those tasks are offered to idle graph workers.  A
    /// node must not call `run_graph` recursively.  `spec` must be acyclic
    /// with every edge's endpoints in range; a cycle deadlocks the run.
    ///
    /// Panics in `node` propagate to the caller after the run drains.
    pub fn run_graph(&self, spec: &GraphSpec<'_>, node: &(dyn Fn(u32, usize) + Sync)) {
        let n = spec.n_nodes();
        assert_eq!(spec.succ_offsets.len(), n + 1, "run_graph offsets length");
        assert_eq!(spec.priority.len(), n, "run_graph priority length");
        if n == 0 {
            return;
        }
        if self.shared.is_none() {
            // serial pool: claim ready nodes in priority order inline
            let mut pending: Vec<u32> = spec.n_preds.to_vec();
            let mut heap: BinaryHeap<(u64, u32)> = BinaryHeap::new();
            for (i, &p) in pending.iter().enumerate() {
                if p == 0 {
                    heap.push((spec.priority[i], i as u32));
                }
            }
            let mut ran = 0usize;
            while let Some((_, i)) = heap.pop() {
                node(i, 0);
                ran += 1;
                for &s in spec.succs_of(i) {
                    pending[s as usize] -= 1;
                    if pending[s as usize] == 0 {
                        heap.push((spec.priority[s as usize], s));
                    }
                }
            }
            assert_eq!(ran, n, "run_graph: dependency cycle");
            return;
        }
        let ctx = Arc::new(GraphCtx {
            q: Mutex::new(GraphQueue { heap: BinaryHeap::new(), help: Vec::new() }),
            cv: Condvar::new(),
            pending: spec.n_preds.iter().map(|&p| AtomicU32::new(p)).collect(),
            retired: AtomicUsize::new(0),
            n,
            abort: AtomicBool::new(false),
        });
        {
            let mut q = ctx.q.lock().unwrap();
            for (i, &p) in spec.n_preds.iter().enumerate() {
                if p == 0 {
                    q.heap.push((spec.priority[i], i as u32));
                }
            }
        }
        // every pool thread becomes a graph worker; panics from nodes are
        // captured by the worker-task machinery and re-raised here by `run`
        self.run(self.threads, &|w| {
            let _guard = GraphCtxGuard::set(Arc::clone(&ctx));
            graph_worker_loop(&ctx, spec, node, w);
        });
        assert!(
            ctx.retired.load(Ordering::Acquire) == n || ctx.abort.load(Ordering::Relaxed),
            "run_graph: workers exited with unretired nodes (dependency cycle?)"
        );
    }

    /// Split `out` (a `rows x row_len` row-major buffer) into contiguous
    /// row blocks of at least `min_rows` rows and run
    /// `f(row_range, block)` over them in parallel.  Blocks are disjoint,
    /// the partition depends only on `rows` and the pool size, and `f`
    /// must fully define the block it is given.
    pub fn par_rows(
        &self,
        rows: usize,
        row_len: usize,
        out: &mut [f64],
        min_rows: usize,
        f: impl Fn(std::ops::Range<usize>, &mut [f64]) + Sync,
    ) {
        assert_eq!(out.len(), rows * row_len, "par_rows buffer size");
        let min_rows = min_rows.max(1);
        let n_tasks = if rows == 0 { 0 } else { self.threads.min(rows.div_ceil(min_rows)).max(1) };
        if n_tasks <= 1 {
            if rows > 0 {
                f(0..rows, out);
            }
            return;
        }
        let base = SyncPtr(out.as_mut_ptr());
        self.run(n_tasks, &|t: usize| {
            let lo = rows * t / n_tasks;
            let hi = rows * (t + 1) / n_tasks;
            if lo >= hi {
                return;
            }
            // SAFETY: [lo, hi) blocks are disjoint across tasks and stay
            // within the `rows * row_len` buffer `base` points into, which
            // outlives `run` (it borrows `out`).  `base.get()` (a &self
            // method) makes the closure capture the Sync wrapper, not the
            // raw pointer field.
            let block = unsafe {
                std::slice::from_raw_parts_mut(base.get().add(lo * row_len), (hi - lo) * row_len)
            };
            f(lo..hi, block);
        });
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::serial()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Some(shared) = self.shared.take() {
            {
                let mut ctl = shared.ctl.lock().unwrap();
                ctl.shutdown = true;
                shared.work_cv.notify_all();
            }
            for h in self.workers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Raw base pointer made shareable so the task closure can slice disjoint
/// blocks out of one `&mut [f64]`.  Access goes through [`SyncPtr::get`]
/// so closures capture the wrapper (Sync) rather than the raw pointer
/// field (not Sync) under edition-2021 disjoint capture.
struct SyncPtr(*mut f64);

impl SyncPtr {
    fn get(&self) -> *mut f64 {
        self.0
    }
}

unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut ctl = shared.ctl.lock().unwrap();
            loop {
                if ctl.shutdown {
                    return;
                }
                if ctl.job.is_some() && ctl.epoch != last_epoch {
                    last_epoch = ctl.epoch;
                    break ctl.job.clone().unwrap();
                }
                ctl = shared.work_cv.wait(ctl).unwrap();
            }
        };
        drain_tasks(job.f, &job.next, &job.done, &job.panic, job.n_tasks);
        // lock before notifying so the submitter is either already waiting
        // or will observe the final count when it re-checks
        let _ctl = shared.ctl.lock().unwrap();
        shared.done_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn serial_pool_runs_inline() {
        let pool = Pool::serial();
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(5, &|t| {
            hits.fetch_add(t + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1 + 2 + 3 + 4 + 5);
    }

    #[test]
    fn every_task_runs_exactly_once() {
        let pool = Pool::new(4);
        let n = 1000;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.run(n, &|t| {
            counts[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = Pool::new(3);
        for round in 0..50usize {
            let sum = AtomicUsize::new(0);
            pool.run(8, &|t| {
                sum.fetch_add(t + round, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 28 + 8 * round);
        }
    }

    #[test]
    fn par_rows_covers_the_buffer_disjointly() {
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let (rows, row_len) = (37, 5);
            let mut out = vec![0.0f64; rows * row_len];
            pool.par_rows(rows, row_len, &mut out, 1, |range, block| {
                assert_eq!(block.len(), (range.end - range.start) * row_len);
                for (off, v) in block.iter_mut().enumerate() {
                    *v += (range.start * row_len + off) as f64;
                }
            });
            // every element written exactly once with its own index
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as f64, "thread count {threads}");
            }
        }
    }

    #[test]
    fn par_rows_respects_min_rows() {
        let pool = Pool::new(8);
        let mut out = vec![0.0f64; 6];
        // 6 rows, min 4 per task -> at most 2 tasks; just check coverage
        pool.par_rows(6, 1, &mut out, 4, |range, block| {
            for (off, v) in block.iter_mut().enumerate() {
                *v = (range.start + off) as f64 + 1.0;
            }
        });
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn empty_and_single_task_jobs() {
        let pool = Pool::new(2);
        pool.run(0, &|_| panic!("no tasks should run"));
        let hits = AtomicUsize::new(0);
        pool.run(1, &|t| {
            assert_eq!(t, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        let mut out: Vec<f64> = Vec::new();
        pool.par_rows(0, 3, &mut out, 1, |_, _| panic!("no rows"));
    }

    #[test]
    fn task_panics_propagate_without_hanging() {
        let pool = Pool::new(3);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, &|t| {
                if t == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(outcome.is_err(), "panic should reach the submitter");
        // the pool survives and the next job runs normally
        let hits = AtomicUsize::new(0);
        pool.run(4, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    /// Build a CSR spec from explicit edge lists (pred -> succ).
    fn spec_from_edges(n: usize, edges: &[(u32, u32)]) -> (Vec<u32>, Vec<u32>, Vec<u32>, Vec<u64>) {
        let mut n_preds = vec![0u32; n];
        let mut succ_offsets = vec![0u32; n + 1];
        for &(from, to) in edges {
            n_preds[to as usize] += 1;
            succ_offsets[from as usize + 1] += 1;
        }
        for i in 0..n {
            succ_offsets[i + 1] += succ_offsets[i];
        }
        let mut cursor: Vec<u32> = succ_offsets[..n].to_vec();
        let mut succs = vec![0u32; edges.len()];
        for &(from, to) in edges {
            succs[cursor[from as usize] as usize] = to;
            cursor[from as usize] += 1;
        }
        (n_preds, succs, succ_offsets, vec![1; n])
    }

    #[test]
    fn run_graph_respects_dependencies_and_runs_every_node_once() {
        // diamond with a tail: 0 -> {1, 2} -> 3 -> 4
        let edges = [(0u32, 1u32), (0, 2), (1, 3), (2, 3), (3, 4)];
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let (n_preds, succs, succ_offsets, priority) = spec_from_edges(5, &edges);
            let spec = GraphSpec {
                n_preds: &n_preds,
                succs: &succs,
                succ_offsets: &succ_offsets,
                priority: &priority,
            };
            let order = Mutex::new(Vec::new());
            let runs: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
            pool.run_graph(&spec, &|i, w| {
                assert!(w < threads, "worker index out of range");
                runs[i as usize].fetch_add(1, Ordering::Relaxed);
                order.lock().unwrap().push(i);
            });
            assert!(runs.iter().all(|r| r.load(Ordering::Relaxed) == 1), "{threads} threads");
            let order = order.lock().unwrap();
            let pos = |n: u32| order.iter().position(|&x| x == n).unwrap();
            for &(from, to) in &edges {
                assert!(pos(from) < pos(to), "{threads} threads: {from} before {to}");
            }
        }
    }

    #[test]
    fn run_graph_prefers_higher_priority_ready_nodes() {
        // serial pool: claim order is deterministic, priority-descending
        // among simultaneously-ready nodes
        let pool = Pool::serial();
        let (n_preds, succs, succ_offsets, _) = spec_from_edges(3, &[]);
        let priority = vec![5u64, 50, 1];
        let spec = GraphSpec {
            n_preds: &n_preds,
            succs: &succs,
            succ_offsets: &succ_offsets,
            priority: &priority,
        };
        let order = Mutex::new(Vec::new());
        pool.run_graph(&spec, &|i, _| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), vec![1, 0, 2]);
    }

    #[test]
    fn run_graph_nodes_can_fork_join_through_the_pool() {
        // a node row-splits through Pool::run while other nodes are in
        // flight: the nested tasks go through the help list
        let pool = Pool::new(4);
        let n = 6usize;
        let (n_preds, succs, succ_offsets, priority) = spec_from_edges(n, &[(0, 5)]);
        let spec = GraphSpec {
            n_preds: &n_preds,
            succs: &succs,
            succ_offsets: &succ_offsets,
            priority: &priority,
        };
        let sums: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run_graph(&spec, &|i, _| {
            let slot = &sums[i as usize];
            pool.run(8, &|t| {
                slot.fetch_add(t + 1, Ordering::Relaxed);
            });
        });
        for (i, s) in sums.iter().enumerate() {
            assert_eq!(s.load(Ordering::Relaxed), 36, "node {i}");
        }
    }

    #[test]
    fn run_graph_panics_propagate_without_hanging() {
        let pool = Pool::new(3);
        let (n_preds, succs, succ_offsets, priority) = spec_from_edges(8, &[(0, 1), (1, 2)]);
        let spec = GraphSpec {
            n_preds: &n_preds,
            succs: &succs,
            succ_offsets: &succ_offsets,
            priority: &priority,
        };
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            pool.run_graph(&spec, &|i, _| {
                if i == 1 {
                    panic!("graph boom");
                }
            });
        }));
        assert!(outcome.is_err(), "panic should reach the submitter");
        // the pool survives: both modes still work
        let hits = AtomicUsize::new(0);
        pool.run(4, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
        let (n_preds, succs, succ_offsets, priority) = spec_from_edges(3, &[]);
        let spec = GraphSpec {
            n_preds: &n_preds,
            succs: &succs,
            succ_offsets: &succ_offsets,
            priority: &priority,
        };
        let ran = AtomicUsize::new(0);
        pool.run_graph(&spec, &|_, _| {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn run_graph_drains_panics_at_every_pool_width() {
        // crash-safety satellite: a panicking node must reach the
        // submitter (no deadlocked claim loop, no stuck worker) at 2 and
        // 4 threads, and the same pool must keep scheduling afterwards
        for threads in [2usize, 4] {
            let pool = Pool::new(threads);
            for round in 0..3usize {
                let edges = [(0u32, 2u32), (1, 2), (2, 3), (3, 4), (3, 5)];
                let (n_preds, succs, succ_offsets, priority) = spec_from_edges(6, &edges);
                let spec = GraphSpec {
                    n_preds: &n_preds,
                    succs: &succs,
                    succ_offsets: &succ_offsets,
                    priority: &priority,
                };
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    pool.run_graph(&spec, &|i, _| {
                        if i as usize == round + 1 {
                            panic!("graph boom at node {i}");
                        }
                    });
                }));
                assert!(outcome.is_err(), "{threads} threads round {round}");
                // drained: an untouched job on the same pool runs clean
                let ran = AtomicUsize::new(0);
                pool.run_graph(&spec, &|_, _| {
                    ran.fetch_add(1, Ordering::Relaxed);
                });
                assert_eq!(ran.load(Ordering::Relaxed), 6, "{threads} threads round {round}");
            }
        }
    }

    #[test]
    fn run_graph_drains_a_panic_while_the_help_list_is_occupied() {
        // a node panics while another node's nested row-split job still
        // has tasks live on the help list: the panic must reach the
        // submitter, the nested fork-join must complete first (its erased
        // borrow dies before unwinding), and the same pool must keep
        // scheduling both modes afterwards
        for threads in [2usize, 4] {
            let pool = Pool::new(threads);
            let (n_preds, succs, succ_offsets, priority) = spec_from_edges(2, &[]);
            let spec = GraphSpec {
                n_preds: &n_preds,
                succs: &succs,
                succ_offsets: &succ_offsets,
                priority: &priority,
            };
            let published = AtomicBool::new(false);
            let release = AtomicBool::new(false);
            let finished = AtomicUsize::new(0);
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                pool.run_graph(&spec, &|i, _| {
                    if i == 0 {
                        // a "heavy kernel": its row blocks sit on the help
                        // list until node 1 releases them
                        pool.run(8, &|_| {
                            published.store(true, Ordering::Release);
                            while !release.load(Ordering::Acquire) {
                                std::thread::yield_now();
                            }
                            finished.fetch_add(1, Ordering::Relaxed);
                        });
                    } else {
                        while !published.load(Ordering::Acquire) {
                            std::thread::yield_now();
                        }
                        release.store(true, Ordering::Release);
                        panic!("boom with help tasks in flight");
                    }
                });
            }));
            assert!(outcome.is_err(), "{threads} threads: panic should reach the submitter");
            assert_eq!(finished.load(Ordering::Relaxed), 8, "{threads} threads: nested drained");
            // the help list is clean: nested fork-join still works
            let sum = AtomicUsize::new(0);
            pool.run_graph(&spec, &|_, _| {
                pool.run(4, &|t| {
                    sum.fetch_add(t + 1, Ordering::Relaxed);
                });
            });
            assert_eq!(sum.load(Ordering::Relaxed), 20, "{threads} threads");
        }
    }

    #[test]
    fn run_graph_empty_graph_is_a_noop() {
        let pool = Pool::new(2);
        let spec = GraphSpec { n_preds: &[], succs: &[], succ_offsets: &[0], priority: &[] };
        pool.run_graph(&spec, &|_, _| panic!("no nodes"));
    }

    #[test]
    fn grain_minimums_scale_with_row_length() {
        assert_eq!(grain::matmul_rows(1, 1), grain::MATMUL_FLOPS_PER_TASK);
        assert!(grain::matmul_rows(1 << 20, 1 << 20) >= 1);
        assert_eq!(grain::elemwise_rows(1), grain::ELEMWISE_PER_TASK);
        assert!(grain::elemwise_rows(usize::MAX) >= 1);
    }

    #[test]
    fn default_threads_reads_env_or_one() {
        // can't mutate the environment safely in a test binary that may run
        // threaded; just pin the parse contract on the current value
        let n = default_threads();
        assert!(n >= 1);
    }
}
