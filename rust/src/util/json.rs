//! Minimal JSON: a recursive-descent parser + a writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); numbers are held as `f64`, which is exact for
//! every integer the artifact manifest contains.

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(char, usize),
    Trailing(usize),
    Type(&'static str),
    MissingKey(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Eof(at) => write!(f, "unexpected end of input at byte {at}"),
            Self::Unexpected(c, at) => write!(f, "unexpected character {c:?} at byte {at}"),
            Self::BadNumber(at) => write!(f, "invalid number at byte {at}"),
            Self::BadEscape(c, at) => write!(f, "invalid escape \\{c} at byte {at}"),
            Self::Trailing(at) => write!(f, "trailing garbage at byte {at}"),
            Self::Type(expected) => write!(f, "type error: expected {expected}"),
            Self::MissingKey(key) => write!(f, "missing key {key:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut pos = 0;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(JsonError::Trailing(pos));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(JsonError::Type("object")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(JsonError::Type("array")),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(JsonError::Type("number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // -- writer ---------------------------------------------------------------

    /// Serialise (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building log records.
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return Err(JsonError::Eof(*pos));
    };
    match c {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_num(b, pos),
        c => Err(JsonError::Unexpected(c as char, *pos)),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json, JsonError> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        Err(JsonError::Unexpected(b[*pos] as char, *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or(JsonError::BadNumber(start))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    *pos += 1; // opening quote
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            return Err(JsonError::Eof(*pos));
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else {
                    return Err(JsonError::Eof(*pos));
                };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            return Err(JsonError::Eof(*pos));
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .map_err(|_| JsonError::BadEscape('u', *pos))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError::BadEscape('u', *pos))?;
                        *pos += 4;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    e => return Err(JsonError::BadEscape(e as char, *pos - 1)),
                }
            }
            c => {
                // copy UTF-8 continuation bytes verbatim
                let len = utf8_len(c);
                out.push_str(
                    std::str::from_utf8(&b[*pos - 1..*pos - 1 + len])
                        .map_err(|_| JsonError::Unexpected(c as char, *pos - 1))?,
                );
                *pos += len - 1;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1;
    let mut out = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            Some(&c) => return Err(JsonError::Unexpected(c as char, *pos)),
            None => return Err(JsonError::Eof(*pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1;
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') {
            return Err(JsonError::Unexpected(
                b.get(*pos).map(|&c| c as char).unwrap_or('\0'),
                *pos,
            ));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(JsonError::Unexpected(
                b.get(*pos).map(|&c| c as char).unwrap_or('\0'),
                *pos,
            ));
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            Some(&c) => return Err(JsonError::Unexpected(c as char, *pos)),
            None => return Err(JsonError::Eof(*pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\"A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo ∂∞\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ∂∞");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,"s",null,true],"n":-7,"obj":{"x":1}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\nc".into());
        assert_eq!(v.to_string(), r#""a\"b\nc""#);
    }

    #[test]
    fn typed_accessors_error_cleanly() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.as_obj().is_err());
        assert!(v.get("x").is_err());
        assert!(matches!(
            Json::parse("{}").unwrap().get("k"),
            Err(JsonError::MissingKey(_))
        ));
    }

    #[test]
    fn obj_builder() {
        let v = obj(vec![("a", 1.5.into()), ("b", "s".into())]);
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn parses_real_meta_json_if_present() {
        // integration smoke: the actual artifact manifest must parse
        if let Ok(text) = std::fs::read_to_string("artifacts/meta.json") {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("artifacts").unwrap().as_obj().unwrap().len() > 0);
        }
    }
}
