//! Hand-rolled infrastructure substrates.
//!
//! This build is fully offline; the usual ecosystem crates (serde, clap,
//! criterion, proptest, tokio) are not available, so the pieces of them this
//! project needs are implemented here, each small and fully tested:
//!
//! * [`json`]   -- JSON parser/writer (reads `artifacts/meta.json`, writes
//!   metric logs),
//! * [`cli`]    -- declarative flag/positional argument parser,
//! * [`benchkit`] -- criterion-style micro-benchmark harness (warmup,
//!   timed iterations, mean/stddev/percentiles, throughput),
//! * [`propkit`]  -- seeded property-testing harness with shrinking,
//! * [`env`]      -- `ZCS_*` environment-knob resolution with the shared
//!   warn-on-typo fallback,
//! * [`pool`]     -- persistent scoped worker pool for the deterministic
//!   data-parallel kernels (the rayon stand-in).

pub mod benchkit;
pub mod cli;
pub mod env;
pub mod json;
pub mod pool;
pub mod propkit;
