//! Micro-benchmark harness (the offline stand-in for `criterion`).
//!
//! Warmup, a fixed measurement budget, outlier-robust statistics, and a
//! table printer shaped like the paper's Figure-2 / Table-1 rows.  The
//! bench binaries under `rust/benches/` are `harness = false` and drive
//! this directly, so `cargo bench` works end to end without criterion.

use std::time::{Duration, Instant};

/// Summary statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub p50: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let mean = sum / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| (d.as_secs_f64() - mean_s).powi(2))
            .sum::<f64>()
            / n as f64;
        Stats {
            iters: n,
            mean,
            stddev: Duration::from_secs_f64(var.sqrt()),
            p50: samples[n / 2],
            min: samples[0],
            max: samples[n - 1],
        }
    }

    /// Milliseconds, convenient for table rows.
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    /// The paper reports "time per 1000 batches" -- scale a per-batch mean.
    pub fn per_1000(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3 // seconds per 1000 iterations
    }
}

/// Benchmark runner with a time budget.
pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

/// Whether the CI smoke mode is requested (`ZCS_BENCH_QUICK` set): benches
/// keep their structure but shrink their measurement budget.
pub fn quick_mode() -> bool {
    std::env::var_os("ZCS_BENCH_QUICK").is_some()
}

impl Bench {
    /// Quick preset for expensive end-to-end steps.
    pub fn heavy() -> Self {
        Self {
            warmup: Duration::from_millis(100),
            budget: Duration::from_secs(3),
            min_iters: 3,
            max_iters: 200,
        }
    }

    /// Smoke preset: a tiny budget that still yields a usable mean.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(120),
            min_iters: 3,
            max_iters: 60,
        }
    }

    /// [`Default`], or [`Bench::quick`] under `ZCS_BENCH_QUICK`.
    pub fn from_env() -> Self {
        if quick_mode() {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// [`Bench::heavy`], or [`Bench::quick`] under `ZCS_BENCH_QUICK`.
    pub fn heavy_from_env() -> Self {
        if quick_mode() {
            Self::quick()
        } else {
            Self::heavy()
        }
    }

    /// Measure `f` repeatedly; each call is one sample.
    pub fn run<R>(&self, mut f: impl FnMut() -> R) -> Stats {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // measurement
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while (b0.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        Stats::from_samples(samples)
    }
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:>w$}  ", w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers, &self.widths);
        println!("{}", "-".repeat(self.widths.iter().sum::<usize>() + 2 * self.widths.len()));
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
        ]);
        assert_eq!(s.iters, 3);
        assert_eq!(s.mean, Duration::from_millis(2));
        assert_eq!(s.p50, Duration::from_millis(2));
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(3));
    }

    #[test]
    fn run_respects_min_iters() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            budget: Duration::ZERO,
            min_iters: 7,
            max_iters: 100,
        };
        let s = b.run(|| 1 + 1);
        assert!(s.iters >= 7);
    }

    #[test]
    fn run_measures_sleepy_fn() {
        let b = Bench {
            warmup: Duration::ZERO,
            budget: Duration::from_millis(30),
            min_iters: 3,
            max_iters: 100,
        };
        let s = b.run(|| std::thread::sleep(Duration::from_millis(2)));
        assert!(s.mean >= Duration::from_millis(2));
        assert!(s.mean < Duration::from_millis(20));
    }

    #[test]
    fn table_row_count_enforced() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }
}
