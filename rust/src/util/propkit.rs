//! Seeded property-testing harness with shrinking (the offline stand-in for
//! `proptest`).
//!
//! A property is a closure over a generated input; the harness runs many
//! random cases and, on failure, greedily shrinks the input before
//! panicking with the minimal counter-example.  Generators are plain
//! functions of [`Pcg64`] plus a shrink function, which keeps the machinery
//! tiny while covering what the invariant tests need (sized vectors,
//! ranges, tuples via composition).

use crate::rng::Pcg64;

/// A reusable generator: produce a value from randomness + shrink candidates.
pub struct Gen<T> {
    pub make: Box<dyn Fn(&mut Pcg64) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        make: impl Fn(&mut Pcg64) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Self { make: Box::new(make), shrink: Box::new(shrink) }
    }
}

/// usize in `[lo, hi]`, shrinking toward `lo`.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    Gen::new(
        move |rng| lo + rng.below(hi - lo + 1),
        move |&v| {
            let mut c = Vec::new();
            if v > lo {
                c.push(lo);
                c.push(lo + (v - lo) / 2);
                c.push(v - 1);
            }
            c.dedup();
            c
        },
    )
}

/// f64 in `[lo, hi)`, shrinking toward the midpoint-free simple values.
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(
        move |rng| rng.uniform_in(lo, hi),
        move |&v| {
            let mut c = Vec::new();
            for cand in [0.0, lo, (lo + hi) / 2.0] {
                if (lo..hi).contains(&cand) && cand != v {
                    c.push(cand);
                }
            }
            c
        },
    )
}

/// Vector of standard normals with length from `len_gen`.
pub fn normal_vec(len_gen: Gen<usize>) -> Gen<Vec<f64>> {
    Gen::new(
        move |rng| {
            let n = (len_gen.make)(rng);
            rng.normals(n)
        },
        |v| {
            let mut c = Vec::new();
            if v.len() > 1 {
                c.push(v[..v.len() / 2].to_vec()); // halve
                c.push(v[..v.len() - 1].to_vec()); // drop one
            }
            if v.iter().any(|&x| x != 0.0) {
                c.push(vec![0.0; v.len()]); // all zeros
                c.push(v.iter().map(|x| x / 2.0).collect()); // damp
            }
            c
        },
    )
}

/// Outcome-bearing property check.
pub struct Runner {
    pub cases: usize,
    pub seed: u64,
    pub max_shrinks: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Self { cases: 64, seed: 0x5eed, max_shrinks: 200 }
    }
}

impl Runner {
    /// Run `prop` on `cases` random inputs; panic with a shrunk
    /// counter-example (debug-formatted) on failure.
    pub fn check<T: Clone + std::fmt::Debug + 'static>(
        &self,
        gen: Gen<T>,
        prop: impl Fn(&T) -> Result<(), String>,
    ) {
        let mut rng = Pcg64::seeded(self.seed);
        for case in 0..self.cases {
            let input = (gen.make)(&mut rng);
            if let Err(first_msg) = prop(&input) {
                // shrink greedily
                let mut best = input;
                let mut best_msg = first_msg;
                let mut budget = self.max_shrinks;
                'outer: while budget > 0 {
                    for cand in (gen.shrink)(&best) {
                        budget -= 1;
                        if let Err(msg) = prop(&cand) {
                            best = cand;
                            best_msg = msg;
                            continue 'outer;
                        }
                        if budget == 0 {
                            break;
                        }
                    }
                    break;
                }
                panic!(
                    "property failed (case {case}, seed {:#x}):\n  input: {best:?}\n  error: {best_msg}",
                    self.seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Runner::default().check(usize_in(0, 100), |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let caught = std::panic::catch_unwind(|| {
            Runner { cases: 200, ..Default::default() }.check(usize_in(0, 1000), |&v| {
                if v < 500 {
                    Ok(())
                } else {
                    Err(format!("{v} too big"))
                }
            });
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink must land on exactly the boundary 500
        assert!(msg.contains("input: 500"), "{msg}");
    }

    #[test]
    fn normal_vec_shrinks_toward_small_and_zero() {
        let g = normal_vec(usize_in(1, 8));
        let mut rng = Pcg64::seeded(1);
        let v = (g.make)(&mut rng);
        let shrunk = (g.shrink)(&v);
        assert!(!shrunk.is_empty());
        if v.len() > 1 {
            assert!(shrunk.iter().any(|s| s.len() < v.len()));
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = || {
            let mut rng = Pcg64::seeded(99);
            let g = usize_in(0, 1_000_000);
            (0..10).map(|_| (g.make)(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(collect(), collect());
    }
}
