//! Seeded property-testing harness with shrinking (the offline stand-in for
//! `proptest`).
//!
//! A property is a closure over a generated input; the harness runs many
//! random cases and, on failure, greedily shrinks the input before
//! panicking with the minimal counter-example.  Generators are plain
//! functions of [`Pcg64`] plus a shrink function, which keeps the machinery
//! tiny while covering what the invariant tests need (sized vectors,
//! ranges, tuples via composition).

use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// Distance between two finite `f64`s in units in the last place: the
/// number of representable doubles strictly between them (0 when equal,
/// 1 for adjacent values).  Uses the standard order-preserving mapping of
/// IEEE-754 bit patterns onto the integer line, so the distance is exact
/// across exponent boundaries and across the `-0.0`/`+0.0` straddle
/// (those two count as 1 apart).  Panics on NaN -- a NaN has no position
/// on the line and a comparison against one is always a bug.
pub fn ulps_between(a: f64, b: f64) -> u64 {
    assert!(!a.is_nan() && !b.is_nan(), "ulps_between({a}, {b}): NaN operand");
    // map the sign-magnitude float encoding onto a monotone unsigned line:
    // negatives reflect below the midpoint, positives shift above it
    fn ord(x: f64) -> u64 {
        let bits = x.to_bits();
        if bits >> 63 == 1 {
            !bits
        } else {
            bits | (1 << 63)
        }
    }
    ord(a).abs_diff(ord(b))
}

/// Assert two floats are within `max_ulps` representable values of each
/// other (see [`ulps_between`]) -- the comparison for kernels whose SIMD
/// lane splits *reassociate* a reduction and therefore cannot promise the
/// scalar bit pattern, only a tightly bounded rounding difference.
/// Order-preserving kernels should keep using `assert_eq!`.
#[track_caller]
pub fn assert_ulps_le(a: f64, b: f64, max_ulps: u64) {
    let d = ulps_between(a, b);
    assert!(d <= max_ulps, "{a} vs {b}: {d} ulps apart (allowed {max_ulps})");
}

/// Assert two tensor slices are identical *bit for bit*: same length,
/// same shapes, and every element's IEEE-754 bit pattern equal (so
/// `-0.0` vs `0.0`, or two different NaN payloads, fail rather than
/// comparing loosely).  This is the assertion for determinism contracts
/// -- resident vs feed-based weights, N-replica vs single-replica
/// trajectories -- where "close" is already a bug; the failure message
/// names the first diverging tensor, element, and both bit patterns.
#[track_caller]
pub fn assert_tensors_bits_eq(got: &[Tensor], want: &[Tensor], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: tensor count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.shape(), w.shape(), "{what}: tensor {i} shape");
        for (j, (a, b)) in g.data().iter().zip(w.data()).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{what}: tensor {i} element {j}: {a} ({:#018x}) vs {b} ({:#018x})",
                a.to_bits(),
                b.to_bits()
            );
        }
    }
}

/// A reusable generator: produce a value from randomness + shrink candidates.
pub struct Gen<T> {
    pub make: Box<dyn Fn(&mut Pcg64) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        make: impl Fn(&mut Pcg64) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Self { make: Box::new(make), shrink: Box::new(shrink) }
    }
}

/// usize in `[lo, hi]`, shrinking toward `lo`.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    Gen::new(
        move |rng| lo + rng.below(hi - lo + 1),
        move |&v| {
            let mut c = Vec::new();
            if v > lo {
                c.push(lo);
                c.push(lo + (v - lo) / 2);
                c.push(v - 1);
            }
            c.dedup();
            c
        },
    )
}

/// f64 in `[lo, hi)`, shrinking toward the midpoint-free simple values.
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(
        move |rng| rng.uniform_in(lo, hi),
        move |&v| {
            let mut c = Vec::new();
            for cand in [0.0, lo, (lo + hi) / 2.0] {
                if (lo..hi).contains(&cand) && cand != v {
                    c.push(cand);
                }
            }
            c
        },
    )
}

/// Vector of standard normals with length from `len_gen`.
pub fn normal_vec(len_gen: Gen<usize>) -> Gen<Vec<f64>> {
    Gen::new(
        move |rng| {
            let n = (len_gen.make)(rng);
            rng.normals(n)
        },
        |v| {
            let mut c = Vec::new();
            if v.len() > 1 {
                c.push(v[..v.len() / 2].to_vec()); // halve
                c.push(v[..v.len() - 1].to_vec()); // drop one
            }
            if v.iter().any(|&x| x != 0.0) {
                c.push(vec![0.0; v.len()]); // all zeros
                c.push(v.iter().map(|x| x / 2.0).collect()); // damp
            }
            c
        },
    )
}

/// Outcome-bearing property check.
pub struct Runner {
    pub cases: usize,
    pub seed: u64,
    pub max_shrinks: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Self { cases: 64, seed: 0x5eed, max_shrinks: 200 }
    }
}

impl Runner {
    /// Run `prop` on `cases` random inputs; panic with a shrunk
    /// counter-example (debug-formatted) on failure.
    pub fn check<T: Clone + std::fmt::Debug + 'static>(
        &self,
        gen: Gen<T>,
        prop: impl Fn(&T) -> Result<(), String>,
    ) {
        let mut rng = Pcg64::seeded(self.seed);
        for case in 0..self.cases {
            let input = (gen.make)(&mut rng);
            if let Err(first_msg) = prop(&input) {
                // shrink greedily
                let mut best = input;
                let mut best_msg = first_msg;
                let mut budget = self.max_shrinks;
                'outer: while budget > 0 {
                    for cand in (gen.shrink)(&best) {
                        budget -= 1;
                        if let Err(msg) = prop(&cand) {
                            best = cand;
                            best_msg = msg;
                            continue 'outer;
                        }
                        if budget == 0 {
                            break;
                        }
                    }
                    break;
                }
                panic!(
                    "property failed (case {case}, seed {:#x}):\n  input: {best:?}\n  error: {best_msg}",
                    self.seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Runner::default().check(usize_in(0, 100), |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let caught = std::panic::catch_unwind(|| {
            Runner { cases: 200, ..Default::default() }.check(usize_in(0, 1000), |&v| {
                if v < 500 {
                    Ok(())
                } else {
                    Err(format!("{v} too big"))
                }
            });
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink must land on exactly the boundary 500
        assert!(msg.contains("input: 500"), "{msg}");
    }

    #[test]
    fn normal_vec_shrinks_toward_small_and_zero() {
        let g = normal_vec(usize_in(1, 8));
        let mut rng = Pcg64::seeded(1);
        let v = (g.make)(&mut rng);
        let shrunk = (g.shrink)(&v);
        assert!(!shrunk.is_empty());
        if v.len() > 1 {
            assert!(shrunk.iter().any(|s| s.len() < v.len()));
        }
    }

    #[test]
    fn ulps_between_counts_representable_gaps() {
        assert_eq!(ulps_between(1.0, 1.0), 0);
        assert_eq!(ulps_between(1.0, f64::from_bits(1.0f64.to_bits() + 1)), 1);
        assert_eq!(ulps_between(1.5, 1.5 - f64::EPSILON), 1); // spacing in [1, 2) is eps
        assert_eq!(ulps_between(-0.0, 0.0), 1);
        assert_eq!(ulps_between(0.0, 0.0), 0);
        // symmetric, and exact across an exponent boundary
        let below = f64::from_bits(2.0f64.to_bits() - 1);
        assert_eq!(ulps_between(2.0, below), 1);
        assert_eq!(ulps_between(below, 2.0), 1);
        // sign straddle: -x .. +x spans both halves of the line
        assert_eq!(
            ulps_between(-f64::MIN_POSITIVE, f64::MIN_POSITIVE),
            ulps_between(-f64::MIN_POSITIVE, 0.0) + ulps_between(0.0, f64::MIN_POSITIVE)
        );
    }

    #[test]
    fn assert_ulps_le_accepts_within_bound() {
        assert_ulps_le(1.0, 1.0, 0);
        assert_ulps_le(1.0, f64::from_bits(1.0f64.to_bits() + 3), 3);
        assert_ulps_le(-2.5, -2.5, 0);
    }

    #[test]
    fn assert_ulps_le_rejects_beyond_bound() {
        let caught = std::panic::catch_unwind(|| {
            assert_ulps_le(1.0, f64::from_bits(1.0f64.to_bits() + 4), 3);
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("4 ulps apart (allowed 3)"), "{msg}");
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn ulps_between_rejects_nan() {
        ulps_between(f64::NAN, 1.0);
    }

    #[test]
    fn tensors_bits_eq_accepts_identical_bits() {
        let a = [Tensor::new(&[2, 2], vec![1.0, -0.0, 3.5, f64::MIN_POSITIVE])];
        let b = [Tensor::new(&[2, 2], vec![1.0, -0.0, 3.5, f64::MIN_POSITIVE])];
        assert_tensors_bits_eq(&a, &b, "identical");
    }

    #[test]
    fn tensors_bits_eq_rejects_signed_zero_drift() {
        // -0.0 == 0.0 under `==`, but they are different bit patterns --
        // exactly the drift a fold-order change would smuggle past assert_eq
        let a = [Tensor::new(&[2], vec![1.0, 0.0])];
        let b = [Tensor::new(&[2], vec![1.0, -0.0])];
        let caught = std::panic::catch_unwind(|| {
            assert_tensors_bits_eq(&a, &b, "zeros");
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("zeros: tensor 0 element 1"), "{msg}");
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn tensors_bits_eq_rejects_shape_mismatch() {
        let a = [Tensor::new(&[2, 1], vec![1.0, 2.0])];
        let b = [Tensor::new(&[1, 2], vec![1.0, 2.0])];
        assert_tensors_bits_eq(&a, &b, "shapes");
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = || {
            let mut rng = Pcg64::seeded(99);
            let g = usize_in(0, 1_000_000);
            (0..10).map(|_| (g.make)(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(collect(), collect());
    }
}
