//! Deterministic, seedable random numbers: PCG64 + Box-Muller normals.
//!
//! Every stochastic component of the coordinator (collocation resampling,
//! GP function sampling, parameter initialisation) draws from this module so
//! that whole training runs are reproducible from a single `u64` seed --
//! a hard requirement for the paper-reproduction harness, where the same
//! batch stream must be replayed under all four AD strategies.

/// PCG-XSL-RR 128/64 (the "pcg64" reference variant, O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// cached second Box-Muller draw
    cached: Option<f64>,
}

/// The complete draw state of a [`Pcg64`], for checkpointing.  The
/// Box-Muller cache is part of the state: a generator restored mid
/// normal-pair must hand out the second half of the pair first, or the
/// resumed stream would be offset by one draw.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pcg64Snapshot {
    pub state: u128,
    pub inc: u128,
    pub cached: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed the generator; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc, cached: None };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output (XSL-RR output function).
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box-Muller (both values used).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached.take() {
            return z;
        }
        // u1 in (0, 1] keeps the log finite
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached = Some(r * s);
        r * c
    }

    /// Fill a buffer with standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Overwrite `out` with standard normals -- the allocation-free
    /// counterpart of [`Pcg64::normals`], drawing the identical sequence.
    pub fn fill_normals(&mut self, out: &mut [f64]) {
        for o in out {
            *o = self.normal();
        }
    }

    /// Fill a buffer with uniforms in `[lo, hi)`.
    pub fn uniforms_in(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Overwrite `out` with uniforms in `[lo, hi)` -- the allocation-free
    /// counterpart of [`Pcg64::uniforms_in`], drawing the identical
    /// sequence.
    pub fn fill_uniforms_in(&mut self, out: &mut [f64], lo: f64, hi: f64) {
        for o in out {
            *o = self.uniform_in(lo, hi);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Capture the complete draw state (see [`Pcg64Snapshot`]).
    pub fn snapshot(&self) -> Pcg64Snapshot {
        Pcg64Snapshot { state: self.state, inc: self.inc, cached: self.cached }
    }

    /// Overwrite this generator with a snapshot: the subsequent draw
    /// sequence is bit-identical to the one the snapshotted generator
    /// would have produced.
    pub fn restore(&mut self, snap: &Pcg64Snapshot) {
        self.state = snap.state;
        self.inc = snap.inc;
        self.cached = snap.cached;
    }

    /// A generator positioned exactly at a snapshot.
    pub fn from_snapshot(snap: &Pcg64Snapshot) -> Self {
        Self { state: snap.state, inc: snap.inc, cached: snap.cached }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher-Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose({k}) from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::seeded(1);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(2);
        let n = 100_000;
        let xs = r.normals(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 2e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 3e-2, "var={var}");
    }

    #[test]
    fn choose_is_distinct_and_in_range() {
        let mut r = Pcg64::seeded(3);
        let picked = r.choose(100, 20);
        assert_eq!(picked.len(), 20);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(picked.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Pcg64::seeded(4);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn snapshot_resumes_mid_box_muller_pair() {
        let mut r = Pcg64::seeded(6);
        // draw an odd number of normals so the Box-Muller cache is full
        let _ = r.normal();
        let snap = r.snapshot();
        assert!(snap.cached.is_some(), "odd draw count must cache the pair's second half");
        let want: Vec<f64> = r.normals(17);
        let mut restored = Pcg64::from_snapshot(&snap);
        assert_eq!(restored.normals(17), want);
        // restore() on a differently-seeded generator converges too
        let mut other = Pcg64::seeded(12345);
        let _ = other.normals(3);
        other.restore(&snap);
        assert_eq!(other.normals(17), want);
        // round-trip: snapshot of a restored generator is the snapshot
        assert_eq!(Pcg64::from_snapshot(&snap).snapshot(), snap);
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg64::seeded(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
