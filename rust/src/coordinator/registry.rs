//! Warm model registry for the serving path.
//!
//! A [`Registry`] maps model ids to trained operators loaded from v2
//! checkpoints ([`super::checkpoint::load_train`]).  Loading validates
//! the checkpoint end to end -- CRC, metadata, problem support, weight
//! shapes -- and rejects anything corrupt or mismatched with a typed
//! [`RegistryError`], so a serve log line tells the operator exactly
//! which file is bad and why.
//!
//! Models are immutable once loaded and handed out as `Arc<Model>`:
//! **hot reload** ([`Registry::load`] on an existing id) swaps the map
//! entry atomically while in-flight requests keep evaluating against
//! the `Arc` they already hold -- nothing is dropped mid-request.  Each
//! load bumps a process-wide generation, which serve workers use to
//! retire cached resident executors compiled against stale weights.
//!
//! The executor-resident half lives in [`ResidentModel`]: an
//! inference-only [`Program`] ([`Program::compile_inference`]) compiled
//! for one `(batch, points)` shape with the model's weights bound as
//! executor state.  Workers build one per coalesced batch shape and
//! reuse it across requests -- the compile-once/run-many machinery the
//! trainer uses, pointed at query traffic.

use crate::autodiff::{Executor, NodeId, Program};
use crate::coordinator::checkpoint::{load_train, CheckpointMeta};
use crate::pde::residual::{build_forward, residual_for, NetDims};
use crate::pde::ProblemKind;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Why a model could not be loaded or looked up.  Every variant names
/// enough context to act on from a log line alone.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// the checkpoint file failed to read, verify, or decode
    Checkpoint { path: String, reason: String },
    /// the checkpoint is intact but this build cannot serve it
    Unsupported { path: String, reason: String },
    /// checkpoint metadata and payload disagree (shape/count drift)
    Mismatched { path: String, reason: String },
    /// no model loaded under this id
    UnknownModel { id: String },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Checkpoint { path, reason } => {
                write!(f, "checkpoint {path:?} rejected: {reason}")
            }
            Self::Unsupported { path, reason } => {
                write!(f, "checkpoint {path:?} unsupported: {reason}")
            }
            Self::Mismatched { path, reason } => {
                write!(f, "checkpoint {path:?} mismatched: {reason}")
            }
            Self::UnknownModel { id } => write!(f, "no model loaded under id {id:?}"),
        }
    }
}

impl std::error::Error for RegistryError {}

/// One loaded operator: immutable trained weights plus everything
/// needed to compile inference programs for it.
#[derive(Debug)]
pub struct Model {
    pub id: String,
    /// registry-wide load counter at load time; a reload of the same id
    /// gets a higher generation, retiring stale resident executors
    pub generation: u64,
    pub meta: CheckpointMeta,
    pub kind: ProblemKind,
    pub dims: NetDims,
    /// wb (q,h), wb2 (h,k), wt (d,h), wt2 (h,k)
    pub weights: Vec<Tensor>,
}

impl Model {
    /// Load and fully validate one v2 checkpoint.
    fn open(id: &str, generation: u64, path: &str) -> Result<Model, RegistryError> {
        let ckpt = load_train(path).map_err(|e| RegistryError::Checkpoint {
            path: path.to_string(),
            reason: format!("{e:#}"),
        })?;
        let meta = ckpt.meta;
        let kind = ProblemKind::parse(&meta.problem)
            .map_err(|reason| RegistryError::Unsupported { path: path.to_string(), reason })?;
        let residual = residual_for(kind).ok_or_else(|| RegistryError::Unsupported {
            path: path.to_string(),
            reason: format!("problem {:?} has no native residual to serve", meta.problem),
        })?;
        let dims = NetDims {
            q: meta.q as usize,
            hidden: meta.hidden as usize,
            k: meta.k as usize,
            coord_dim: residual.coord_dim(),
        };
        let mismatch =
            |reason: String| RegistryError::Mismatched { path: path.to_string(), reason };
        if ckpt.weights.len() != 4 {
            return Err(mismatch(format!(
                "expected 4 weight tensors (wb, wb2, wt, wt2), found {}",
                ckpt.weights.len()
            )));
        }
        let want: [Vec<usize>; 4] = [
            vec![dims.q, dims.hidden],
            vec![dims.hidden, dims.k],
            vec![dims.coord_dim, dims.hidden],
            vec![dims.hidden, dims.k],
        ];
        for (i, (w, want)) in ckpt.weights.iter().zip(&want).enumerate() {
            if w.shape() != &want[..] {
                return Err(mismatch(format!(
                    "weight {i} has shape {:?}, metadata implies {want:?}",
                    w.shape()
                )));
            }
        }
        Ok(Model { id: id.to_string(), generation, meta, kind, dims, weights: ckpt.weights })
    }

    /// Compile an inference-only resident program for this model at one
    /// `(batch, points)` shape and bind the trained weights.
    pub fn resident(self: &Arc<Self>, m: usize, n_pts: usize, threads: usize) -> ResidentModel {
        let fg = build_forward(m, self.dims, n_pts);
        let program = Program::compile_inference(&fg.graph, &[fg.u], &fg.weight_ids);
        let mut exec = Executor::with_threads(threads);
        exec.bind_states(&program, self.weights.clone());
        ResidentModel {
            model: Arc::clone(self),
            program,
            p: fg.p,
            coords: fg.coords,
            m,
            n_pts,
            exec,
        }
    }
}

/// An inference Program warm in its own executor: weights live in
/// resident state, each call is one multi-sample batched run.
pub struct ResidentModel {
    pub model: Arc<Model>,
    program: Program,
    p: NodeId,
    coords: Vec<NodeId>,
    m: usize,
    n_pts: usize,
    exec: Executor,
}

impl ResidentModel {
    pub fn batch_size(&self) -> usize {
        self.m
    }

    pub fn n_pts(&self) -> usize {
        self.n_pts
    }

    /// Evaluate one coalesced batch: `sensors` holds one q-row per
    /// sample, `points` is the shared point-major coordinate block
    /// (`n_pts * coord_dim` values).  Returns one value row per sample.
    ///
    /// Panics on shape mismatch -- serve validates requests at
    /// admission, so a panic here is a real bug (or an injected fault)
    /// and is absorbed by the worker's panic isolation.
    pub fn eval(&mut self, sensors: &[&[f64]], points: &[f64]) -> Vec<Vec<f64>> {
        let dim = self.model.dims.coord_dim;
        assert_eq!(points.len(), self.n_pts * dim, "coordinate block shape");
        let columns: Vec<Tensor> = (0..dim)
            .map(|c| {
                let col: Vec<f64> = (0..self.n_pts).map(|i| points[i * dim + c]).collect();
                Tensor::new(&[self.n_pts, 1], col)
            })
            .collect();
        let mut shared: HashMap<NodeId, &Tensor> = HashMap::new();
        for (node, col) in self.coords.iter().zip(&columns) {
            shared.insert(*node, col);
        }
        let rows = self.exec.run_inference(&self.program, self.p, sensors, &shared);
        if let Some(trip) = self.exec.take_trip() {
            // under ZCS_SANITIZE=full the executor's tripwires are armed;
            // surface a trip as a panic so the serve worker's existing
            // isolation turns it into one bounded retry on a fresh
            // executor, then a typed EvalFailed carrying this report
            panic!("{trip}");
        }
        rows
    }
}

/// The warm model map: id -> loaded model, hot-reloadable.
pub struct Registry {
    models: RwLock<HashMap<String, Arc<Model>>>,
    generation: AtomicU64,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Self { models: RwLock::new(HashMap::new()), generation: AtomicU64::new(0) }
    }

    /// Load (or hot-reload) a checkpoint under `id`.  On success the new
    /// model replaces any previous one atomically; requests already
    /// holding the old `Arc<Model>` finish against it undisturbed.  On
    /// failure the registry is untouched -- a bad reload never evicts a
    /// good model.
    pub fn load(&self, id: &str, path: &str) -> Result<Arc<Model>, RegistryError> {
        let generation = self.generation.fetch_add(1, Ordering::Relaxed) + 1;
        let model = Arc::new(Model::open(id, generation, path)?);
        let mut map = self.models.write().expect("registry lock");
        map.insert(id.to_string(), Arc::clone(&model));
        Ok(model)
    }

    /// The current model under `id`.
    pub fn get(&self, id: &str) -> Result<Arc<Model>, RegistryError> {
        let map = self.models.read().expect("registry lock");
        map.get(id).cloned().ok_or_else(|| RegistryError::UnknownModel { id: id.to_string() })
    }

    /// Loaded ids, sorted (for logs and `zcs serve` startup output).
    pub fn ids(&self) -> Vec<String> {
        let map = self.models.read().expect("registry lock");
        let mut ids: Vec<String> = map.keys().cloned().collect();
        ids.sort();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::checkpoint::{save_train, TrainCheckpoint};
    use crate::rng::{Pcg64, Pcg64Snapshot};

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("zcs_registry_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id())).to_string_lossy().into_owned()
    }

    fn sample_meta() -> CheckpointMeta {
        CheckpointMeta {
            problem: "reaction_diffusion".into(),
            strategy: "zcs".into(),
            optimizer: "adam".into(),
            m: 4,
            n: 16,
            n_bc: 8,
            q: 5,
            hidden: 8,
            k: 4,
            lr: 1e-3,
            seed: 7,
            bank_size: 8,
            bank_grid: 32,
            replicas: 1,
            threads: 1,
            simd: "off".into(),
        }
    }

    fn sample_ckpt() -> TrainCheckpoint {
        let meta = sample_meta();
        let (q, h, k) = (meta.q as usize, meta.hidden as usize, meta.k as usize);
        let mut rng = Pcg64::new(3, 5);
        let mut w = |shape: &[usize]| {
            let n: usize = shape.iter().product();
            Tensor::new(shape, rng.normals(n))
        };
        TrainCheckpoint {
            meta,
            step: 3,
            opt_t: 3,
            rng: Pcg64Snapshot { state: 1, inc: 2, cached: None },
            weights: vec![w(&[q, h]), w(&[h, k]), w(&[2, h]), w(&[h, k])],
            moments: Vec::new(),
        }
    }

    #[test]
    fn loads_serves_and_hot_reloads() {
        let path = tmp("good.ckpt");
        save_train(&path, &sample_ckpt(), None).unwrap();
        let reg = Registry::new();
        let model = reg.load("op", &path).unwrap();
        assert_eq!(model.kind, ProblemKind::ReactionDiffusion);
        assert_eq!(model.dims.coord_dim, 2);
        assert_eq!(reg.ids(), vec!["op".to_string()]);

        // a resident executor answers a batch, values finite
        let mut resident = model.resident(2, 3, 1);
        let s0 = vec![0.1; 5];
        let s1 = vec![-0.2; 5];
        let points = vec![0.25, 0.5, 0.5, 0.5, 0.75, 0.5];
        let rows = resident.eval(&[&s0, &s1], &points);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.len() == 3 && r.iter().all(|v| v.is_finite())));

        // hot reload bumps the generation; the old Arc stays usable
        let reloaded = reg.load("op", &path).unwrap();
        assert!(reloaded.generation > model.generation);
        let rows2 = resident.eval(&[&s0, &s1], &points);
        assert_eq!(rows.len(), rows2.len());
    }

    #[test]
    fn rejects_corrupt_and_mismatched_checkpoints_typed() {
        let reg = Registry::new();

        // corrupt bytes -> Checkpoint, message names the path
        let bad = tmp("corrupt.ckpt");
        std::fs::write(&bad, b"ZCSCKPT2 definitely not a checkpoint").unwrap();
        match reg.load("bad", &bad).unwrap_err() {
            RegistryError::Checkpoint { path, reason } => {
                assert!(path.contains("corrupt.ckpt"), "{path}");
                assert!(!reason.is_empty());
            }
            other => panic!("expected Checkpoint error, got {other:?}"),
        }

        // unsupported problem -> Unsupported
        let mut ckpt = sample_ckpt();
        ckpt.meta.problem = "stokes".into();
        let uns = tmp("unsupported.ckpt");
        save_train(&uns, &ckpt, None).unwrap();
        assert!(matches!(reg.load("uns", &uns).unwrap_err(), RegistryError::Unsupported { .. }));

        // weight shapes disagreeing with the metadata -> Mismatched
        let mut ckpt = sample_ckpt();
        ckpt.weights[0] = Tensor::zeros(&[3, 3]);
        let mis = tmp("mismatched.ckpt");
        save_train(&mis, &ckpt, None).unwrap();
        assert!(matches!(reg.load("mis", &mis).unwrap_err(), RegistryError::Mismatched { .. }));

        // nothing bad ever landed in the map
        assert!(reg.ids().is_empty());
        assert!(matches!(reg.get("bad").unwrap_err(), RegistryError::UnknownModel { .. }));
    }
}
