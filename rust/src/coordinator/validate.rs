//! Validation: relative-L2 error of the trained operator against the
//! independent Rust reference solvers (the paper's "Relative error" column).
//!
//! The trained parameters are pushed through the strategy-independent
//! `forward` artifact on a 64 x 64 evaluation grid; the same input functions
//! are handed to the matching solver in `crate::solvers`; errors are
//! aggregated per output channel over all validation functions.

use crate::config::RunConfig;
use crate::coordinator::batch::Batcher;
use crate::pde::ProblemKind;
use crate::rng::Pcg64;
use crate::runtime::{HostTensor, RunArg, Runtime};
use crate::sampler::tensor_grid_2d;
use crate::solvers::{BurgersSolver, KirchhoffSolver, ReactionDiffusionSolver, StokesSolver};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};

/// Grid points used by the `forward_G4096` artifacts (64 x 64).
pub const GRID_SIDE: usize = 64;

/// Run validation; returns one relative-L2 error per output channel.
pub fn validate(
    runtime: &Runtime,
    kind: ProblemKind,
    config: &RunConfig,
    params: &[HostTensor],
    batcher: &mut Batcher,
) -> Result<Vec<f64>> {
    if matches!(kind, ProblemKind::HighOrder(_) | ProblemKind::Antiderivative) {
        // pure scaling benchmark / native-only toy: no artifact truth to test
        return Ok(Vec::new());
    }
    let g = GRID_SIDE * GRID_SIDE;
    let fwd_name = format!("{}__forward_G{}", kind.name(), g);
    let exe = runtime
        .load(&fwd_name)
        .map_err(|e| anyhow!("{fwd_name}: {e} (build the core artifact set)"))?;
    let m = exe.meta.inputs[exe.meta.inputs.len() - 2].shape[0];

    // evaluation grid, shared with the solvers
    let grid = tensor_grid_2d(GRID_SIDE, GRID_SIDE);
    let pts: Vec<(f64, f64)> = (0..g).map(|r| (grid.at2(r, 0), grid.at2(r, 1))).collect();

    // deterministic validation inputs (separate stream from training)
    let mut vrng = Pcg64::new(config.seed, 99);

    // build p and the per-channel truth
    let n_out = kind.n_out();
    let (p, truth) = match kind {
        ProblemKind::ReactionDiffusion => {
            let functions: Vec<usize> = (0..m).collect();
            let p = batcher.sensors_for(&functions);
            let bank = batcher.bank().unwrap();
            let solver = ReactionDiffusionSolver::default();
            let xs = Tensor::linspace(0.0, 1.0, solver.nx).into_data();
            let mut truth = vec![Vec::with_capacity(m * g)];
            for &fi in &functions {
                let f = bank.eval_many(fi, &xs);
                truth[0].extend(solver.solve_at(&f, &pts));
            }
            (p, truth)
        }
        ProblemKind::Burgers => {
            let functions: Vec<usize> = (0..m).collect();
            let p = batcher.sensors_for(&functions);
            let bank = batcher.bank().unwrap();
            let solver = BurgersSolver::default();
            let xs: Vec<f64> = (0..solver.nx).map(|i| i as f64 / solver.nx as f64).collect();
            let mut truth = vec![Vec::with_capacity(m * g)];
            for &fi in &functions {
                let u0 = bank.eval_many(fi, &xs);
                truth[0].extend(solver.solve_at(&u0, &pts));
            }
            (p, truth)
        }
        ProblemKind::Kirchhoff => {
            let q = batcher.q();
            let coeffs = vrng.normals(m * q);
            let p = HostTensor::from_f64(vec![m, q], &coeffs);
            let solver = KirchhoffSolver::default();
            let mut truth = vec![Vec::with_capacity(m * g)];
            for i in 0..m {
                truth[0].extend(solver.solve_at(&coeffs[i * q..(i + 1) * q], &pts));
            }
            (p, truth)
        }
        ProblemKind::Stokes => {
            let functions: Vec<usize> = (0..m).collect();
            let p = batcher.sensors_for(&functions);
            let bank = batcher.bank().unwrap();
            let solver = StokesSolver::default();
            let xs = Tensor::linspace(0.0, 1.0, solver.n).into_data();
            let mut truth = vec![Vec::with_capacity(m * g); 3];
            for &fi in &functions {
                let lid = bank.eval_many(fi, &xs);
                let fields = solver.solve(&lid);
                for &(x, y) in &pts {
                    let (u, v, pr) = fields.at(x, y);
                    truth[0].push(u);
                    truth[1].push(v);
                    truth[2].push(pr);
                }
            }
            (p, truth)
        }
        ProblemKind::HighOrder(_) | ProblemKind::Antiderivative => unreachable!(),
    };

    // forward pass through the trained operator
    let mut args: Vec<RunArg> = params.iter().cloned().map(RunArg::F32).collect();
    args.push(RunArg::F32(p));
    args.push(RunArg::F32(HostTensor::from_f64(vec![g, 2], grid.data())));
    let out = exe.run(&args)?;
    let u = &out[0];
    if u.dims != vec![n_out, m, g] {
        bail!("forward output {:?}, expected {:?}", u.dims, vec![n_out, m, g]);
    }

    // per-channel relative L2 over all functions and grid points
    let mut errors = Vec::with_capacity(n_out);
    for o in 0..n_out {
        let pred = &u.data[o * m * g..(o + 1) * m * g];
        let tru = &truth[o];
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in pred.iter().zip(tru) {
            num += (*a as f64 - b) * (*a as f64 - b);
            den += b * b;
        }
        errors.push((num / den.max(1e-300)).sqrt());
    }
    Ok(errors)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_side_matches_forward_artifact_convention() {
        assert_eq!(GRID_SIDE * GRID_SIDE, 4096);
    }
}
