//! L3 coordinator: the training orchestrator driving the PJRT artifacts.
//!
//! Responsibilities (Python is long gone by the time this runs):
//!
//! * [`params`]     -- parameter initialisation per the manifest layout;
//! * [`batch`]      -- per-problem batch assembly: GP function selection,
//!   collocation resampling, auxiliary-field interpolation (the paper's
//!   "Inputs" stage);
//! * [`Trainer`]    -- the train loop: feed `train_step` executables, track
//!   losses and stage timings, checkpoint;
//! * [`validate`]   -- relative-L2 error of the trained operator against the
//!   independent Rust solvers through the `forward` artifact (the paper's
//!   "Relative error" column);
//! * [`checkpoint`] -- binary save/load of the flat parameter tuple;
//! * [`native`]     -- an artifact-free training loop driving *compiled*
//!   native autodiff programs (see [`crate::autodiff::program`]) through
//!   the same compile-once/run-many shape as the PJRT path; the physics
//!   comes from the native residual layer ([`crate::pde::residual`]), so
//!   it trains the real case studies (reaction-diffusion, Burgers,
//!   Kirchhoff) as well as the antiderivative toy.  The optimizer (SGD
//!   *or* bias-corrected Adam, `--optimizer`) runs **inside** the
//!   compiled step program: weights and Adam moments stay resident in
//!   the executor and are updated in place, so one program execution is
//!   the whole training step;
//! * [`replica`]    -- data-parallel replica executors for the native
//!   path: the function (branch) dimension is sharded into canonical
//!   lane blocks, each replica compiles and runs its own step Program on
//!   its own persistent [`crate::util::pool::Pool`] (the thread budget
//!   is split across replicas), and gradients fold through a
//!   deterministic fixed-order in-Program all-reduce
//!   ([`crate::autodiff::program::OpCode::GradAllReduce`]) so N-replica
//!   trajectories bit-match single-replica runs.  The native trainer is
//!   no longer a single-pool/single-executor loop -- it owns a
//!   [`replica::ReplicaSet`].

pub mod batch;
pub mod checkpoint;
pub mod error;
pub mod fields;
pub mod native;
pub mod params;
pub mod registry;
pub mod replica;
pub mod validate;

use crate::config::RunConfig;
use crate::pde::ProblemKind;
use crate::runtime::{Executable, HostTensor, RunArg, Runtime};
use anyhow::{anyhow, Context, Result};
use std::rc::Rc;
use std::time::{Duration, Instant};

/// One logged point of the loss curve.
#[derive(Clone, Debug)]
pub struct LogPoint {
    pub step: usize,
    pub loss: f32,
    pub loss_pde: f32,
    pub loss_bc: f32,
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub config: RunConfig,
    pub curve: Vec<LogPoint>,
    pub final_loss: f32,
    pub steps: usize,
    /// wall time spent generating batches (the paper's "Inputs" stage)
    pub input_time: Duration,
    /// wall time inside PJRT train-step execution
    pub step_time: Duration,
    pub compile_time: Duration,
    /// per-channel relative L2 validation error, if requested
    pub validation: Option<Vec<f64>>,
}

impl TrainReport {
    /// Paper-style "time per 1000 batches" in seconds.
    pub fn sec_per_1000(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.step_time.as_secs_f64() / self.steps as f64 * 1000.0
    }
}

/// Training state: flat parameter/Adam tuples + the step counter.
pub struct TrainState {
    pub params: Vec<HostTensor>,
    pub adam_m: Vec<HostTensor>,
    pub adam_v: Vec<HostTensor>,
    pub step: i32,
}

impl TrainState {
    pub fn init(layout: &[(String, Vec<usize>)], rng: &mut crate::rng::Pcg64) -> Self {
        let params = params::init_params(layout, rng);
        let adam_m = params.iter().map(|p| HostTensor::zeros(&p.dims)).collect();
        let adam_v = params.iter().map(|p| HostTensor::zeros(&p.dims)).collect();
        Self { params, adam_m, adam_v, step: 0 }
    }
}

/// The training orchestrator.
pub struct Trainer {
    pub runtime: Rc<Runtime>,
    pub config: RunConfig,
    pub kind: ProblemKind,
    exe: Rc<Executable>,
    batcher: batch::Batcher,
    pub state: TrainState,
}

impl Trainer {
    pub fn new(runtime: Rc<Runtime>, config: RunConfig) -> Result<Self> {
        let kind = ProblemKind::from_name(&config.problem)
            .ok_or_else(|| anyhow!("unknown problem {}", config.problem))?;
        let exe = runtime
            .load(&config.train_artifact())
            .with_context(|| format!("loading {}", config.train_artifact()))?;
        let mut rng = crate::rng::Pcg64::new(config.seed, 1);
        let batcher = batch::Batcher::new(kind, &exe.meta, &config, &mut rng)?;
        let mut init_rng = crate::rng::Pcg64::new(config.seed, 2);
        let state = TrainState::init(&exe.meta.param_layout, &mut init_rng);
        Ok(Self { runtime, config, kind, exe, batcher, state })
    }

    /// Run the configured number of steps.
    pub fn run(&mut self) -> Result<TrainReport> {
        let mut curve = Vec::new();
        let mut input_time = Duration::ZERO;
        let mut step_time = Duration::ZERO;
        let np = self.exe.meta.n_params;
        let mut last = LogPoint { step: 0, loss: f32::NAN, loss_pde: 0.0, loss_bc: 0.0 };
        for it in 0..self.config.steps {
            let t0 = Instant::now();
            let batch = self.batcher.next_batch()?;
            input_time += t0.elapsed();

            let t1 = Instant::now();
            let mut args: Vec<RunArg> = Vec::with_capacity(3 * np + 1 + batch.len());
            args.extend(self.state.params.iter().cloned().map(RunArg::F32));
            args.extend(self.state.adam_m.iter().cloned().map(RunArg::F32));
            args.extend(self.state.adam_v.iter().cloned().map(RunArg::F32));
            args.push(RunArg::I32(self.state.step));
            args.extend(batch);
            let out = self.exe.run(&args)?;
            step_time += t1.elapsed();

            self.state.params = out[..np].to_vec();
            self.state.adam_m = out[np..2 * np].to_vec();
            self.state.adam_v = out[2 * np..3 * np].to_vec();
            self.state.step = out[3 * np].data[0] as i32;
            last = LogPoint {
                step: it + 1,
                loss: out[3 * np + 1].data[0],
                loss_pde: out[3 * np + 2].data[0],
                loss_bc: out[3 * np + 3].data[0],
            };
            if (it + 1) % self.config.log_every == 0 || it + 1 == self.config.steps {
                curve.push(last.clone());
            }
            if !last.loss.is_finite() {
                anyhow::bail!("loss diverged at step {}: {}", it + 1, last.loss);
            }
        }
        let validation = if self.config.validate {
            Some(self.validate()?)
        } else {
            None
        };
        if let Some(path) = &self.config.checkpoint {
            checkpoint::save(path, &self.state.params)?;
        }
        Ok(TrainReport {
            config: self.config.clone(),
            final_loss: last.loss,
            steps: self.config.steps,
            curve,
            input_time,
            step_time,
            compile_time: self.exe.compile_time,
            validation,
        })
    }

    /// Relative-L2 validation error per output channel.
    pub fn validate(&mut self) -> Result<Vec<f64>> {
        validate::validate(
            &self.runtime,
            self.kind,
            &self.config,
            &self.state.params,
            &mut self.batcher,
        )
    }

    pub fn batcher(&mut self) -> &mut batch::Batcher {
        &mut self.batcher
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_sec_per_1000_scaling() {
        let r = TrainReport {
            config: RunConfig::default(),
            curve: vec![],
            final_loss: 0.0,
            steps: 10,
            input_time: Duration::ZERO,
            step_time: Duration::from_millis(50),
            compile_time: Duration::ZERO,
            validation: None,
        };
        assert!((r.sec_per_1000() - 5.0).abs() < 1e-9);
    }
}
