//! Per-problem batch assembly (the paper's "Inputs" stage).
//!
//! Every step the coordinator resamples collocation points uniformly over
//! the domain, picks a fresh subset of input functions from the GP bank, and
//! interpolates whatever auxiliary fields the physics loss needs at exactly
//! those points.  Array order and shapes follow the manifest `batch_schema`
//! byte for byte -- the Rust/Python contract is positional.

use crate::config::RunConfig;
use crate::pde::ProblemKind;
use crate::rng::Pcg64;
use crate::runtime::{ArtifactMeta, HostTensor, RunArg};
use crate::sampler::{boundary_points_2d, interior_points_2d, Edge, FunctionBank, GpSampler1d, Kernel};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Stateful batch generator bound to one (problem, artifact) pair.
pub struct Batcher {
    kind: ProblemKind,
    m: usize,
    q: usize,
    schema: Vec<(String, Vec<usize>)>,
    /// GP input-function bank (None for Kirchhoff / coefficient problems)
    bank: Option<FunctionBank>,
    rng: Pcg64,
    /// function indices used by the most recent batch
    last_functions: Vec<usize>,
    /// most recent Kirchhoff coefficient draw (row-major M x Q)
    last_coeffs: Vec<f64>,
}

impl Batcher {
    pub fn new(
        kind: ProblemKind,
        meta: &ArtifactMeta,
        config: &RunConfig,
        rng: &mut Pcg64,
    ) -> Result<Self> {
        let (p_name, p_shape) = &meta.batch_schema[0];
        if p_name != "p" {
            bail!("batch schema must start with 'p', got {p_name}");
        }
        let (m, q) = (p_shape[0], p_shape[1]);
        let bank = match kind.function_prior() {
            Some(kernel) => {
                let sampler = GpSampler1d::new(kernel, config.bank_grid);
                let mut bank = FunctionBank::generate(&sampler, config.bank_size, rng)?;
                if kind.lid_mask() {
                    bank = bank.masked(|x| x * (1.0 - x));
                }
                Some(bank)
            }
            None => None,
        };
        Ok(Self {
            kind,
            m,
            q,
            schema: meta.batch_schema.clone(),
            bank,
            rng: rng.clone(),
            last_functions: Vec::new(),
            last_coeffs: Vec::new(),
        })
    }

    pub fn bank(&self) -> Option<&FunctionBank> {
        self.bank.as_ref()
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn q(&self) -> usize {
        self.q
    }

    pub fn last_functions(&self) -> &[usize] {
        &self.last_functions
    }

    pub fn last_coeffs(&self) -> &[f64] {
        &self.last_coeffs
    }

    /// Build the sensor matrix `p` for an explicit set of bank functions.
    pub fn sensors_for(&self, functions: &[usize]) -> HostTensor {
        let bank = self.bank.as_ref().expect("problem has a function bank");
        let mut data = Vec::with_capacity(functions.len() * self.q);
        for &fi in functions {
            data.extend(bank.sensors(fi, self.q).iter().map(|&v| v as f32));
        }
        HostTensor::new(vec![functions.len(), self.q], data)
    }

    /// Next training batch, in manifest order.
    pub fn next_batch(&mut self) -> Result<Vec<RunArg>> {
        // 1. pick the function subset for this batch
        match self.kind {
            ProblemKind::Kirchhoff => {
                self.last_coeffs = self.rng.normals(self.m * self.q);
            }
            _ => {
                let bank_len = self.bank.as_ref().map(|b| b.len()).unwrap_or(0);
                self.last_functions = self.rng.choose(bank_len, self.m.min(bank_len));
            }
        }
        // 2. interior points first (several aux fields need them)
        let x_in_shape = self
            .schema
            .iter()
            .find(|(n, _)| n == "x_in")
            .map(|(_, s)| s.clone())
            .expect("schema has x_in");
        let x_in = interior_points_2d(&mut self.rng, x_in_shape[0], (0.0, 1.0), (0.0, 1.0));

        let mut out = Vec::with_capacity(self.schema.len());
        // shared temp: paired t-values for periodic BCs
        let mut periodic_ts: Vec<f64> = Vec::new();
        let mut lid_xs: Vec<f64> = Vec::new();
        for (name, shape) in self.schema.clone() {
            let arg: HostTensor = match name.as_str() {
                "p" => match self.kind {
                    ProblemKind::Kirchhoff => HostTensor::from_f64(
                        vec![self.m, self.q],
                        &self.last_coeffs,
                    ),
                    _ => self.sensors_for(&self.last_functions.clone()),
                },
                "x_in" => HostTensor::from_f64(x_in.shape().to_vec(), x_in.data()),
                // rd: source f evaluated at the interior x-coordinates
                "f_at_x" => self.aux_at_dim0(&x_in, shape[1]),
                // t = 0 line
                "x_ic" => {
                    let (pts, _free) = boundary_points_2d(&mut self.rng, shape[0], Edge::D1Lo);
                    HostTensor::from_f64(pts.shape().to_vec(), pts.data())
                }
                // burgers: u0 at the IC points (must match x_ic's abscissae):
                // regenerate deterministically from the previous entry
                "u0_ic" => {
                    // x_ic was pushed immediately before u0_ic by schema order
                    let prev = out.last().expect("x_ic precedes u0_ic");
                    let RunArg::F32(x_ic) = prev else { unreachable!() };
                    let xs: Vec<f64> =
                        (0..x_ic.dims[0]).map(|r| x_ic.data[2 * r] as f64).collect();
                    self.aux_at_xs(&xs, shape[1])
                }
                "x_bc" => self.dirichlet_edges(shape[0]),
                "x_left" => {
                    periodic_ts = self.rng.uniforms_in(shape[0], 0.0, 1.0);
                    let mut data = Vec::with_capacity(2 * shape[0]);
                    for &t in &periodic_ts {
                        data.push(0.0f32);
                        data.push(t as f32);
                    }
                    HostTensor::new(shape.clone(), data)
                }
                "x_right" => {
                    let mut data = Vec::with_capacity(2 * shape[0]);
                    for &t in &periodic_ts {
                        data.push(1.0f32);
                        data.push(t as f32);
                    }
                    HostTensor::new(shape.clone(), data)
                }
                "x_lid" => {
                    let (pts, free) = boundary_points_2d(&mut self.rng, shape[0], Edge::D1Hi);
                    lid_xs = free;
                    HostTensor::from_f64(pts.shape().to_vec(), pts.data())
                }
                "u1_lid" => self.aux_at_xs(&lid_xs, shape[1]),
                "x_bot" => {
                    let (pts, _) = boundary_points_2d(&mut self.rng, shape[0], Edge::D1Lo);
                    HostTensor::from_f64(pts.shape().to_vec(), pts.data())
                }
                "x_lr" => self.lr_edges(shape[0]),
                other => bail!("unknown batch array {other:?} in schema"),
            };
            if arg.dims != shape {
                bail!("batch array {name}: built {:?}, schema wants {:?}", arg.dims, shape);
            }
            out.push(RunArg::F32(arg));
        }
        Ok(out)
    }

    /// Aux field: bank functions evaluated at the dim-0 coordinate of `pts`.
    fn aux_at_dim0(&self, pts: &Tensor, n: usize) -> HostTensor {
        let xs: Vec<f64> = (0..n).map(|r| pts.at2(r, 0)).collect();
        self.aux_at_xs(&xs, n)
    }

    /// Aux field: bank functions evaluated at explicit abscissae, (M, n).
    fn aux_at_xs(&self, xs: &[f64], n: usize) -> HostTensor {
        assert_eq!(xs.len(), n);
        let bank = self.bank.as_ref().expect("problem has a function bank");
        let mut data = Vec::with_capacity(self.m * n);
        for &fi in &self.last_functions {
            data.extend(bank.eval_many(fi, xs).iter().map(|&v| v as f32));
        }
        HostTensor::new(vec![self.m, n], data)
    }

    /// Dirichlet boundary points: rd -> x = 0/1 edges; kirchhoff -> all four.
    fn dirichlet_edges(&mut self, n: usize) -> HostTensor {
        let edges: &[Edge] = match self.kind {
            ProblemKind::ReactionDiffusion => &[Edge::D0Lo, Edge::D0Hi],
            _ => &[Edge::D0Lo, Edge::D0Hi, Edge::D1Lo, Edge::D1Hi],
        };
        let mut data = Vec::with_capacity(2 * n);
        for i in 0..n {
            let edge = edges[i % edges.len()];
            let (pts, _) = boundary_points_2d(&mut self.rng, 1, edge);
            data.push(pts.data()[0] as f32);
            data.push(pts.data()[1] as f32);
        }
        HostTensor::new(vec![n, 2], data)
    }

    /// Left/right wall points for Stokes.
    fn lr_edges(&mut self, n: usize) -> HostTensor {
        let mut data = Vec::with_capacity(2 * n);
        for i in 0..n {
            let edge = if i % 2 == 0 { Edge::D0Lo } else { Edge::D0Hi };
            let (pts, _) = boundary_points_2d(&mut self.rng, 1, edge);
            data.push(pts.data()[0] as f32);
            data.push(pts.data()[1] as f32);
        }
        HostTensor::new(vec![n, 2], data)
    }
}

/// Batch generator for the *native* engine (no artifacts, no PJRT): draws
/// M sensor rows from a GP function bank and resamples N 1-D collocation
/// points each step, plus the per-point function values the native
/// antiderivative objective fits against.  The native counterpart of
/// [`Batcher`], feeding compiled [`crate::autodiff::Program`]s in
/// [`crate::coordinator::native::NativeTrainer`].
pub struct NativeBatcher {
    bank: FunctionBank,
    m: usize,
    q: usize,
    n: usize,
    rng: Pcg64,
    last_functions: Vec<usize>,
}

/// One native batch, in `f64` [`Tensor`] form.
pub struct NativeBatch {
    /// sensor matrix (M, Q)
    pub p: Tensor,
    /// collocation points (N, 1) in [0, 1)
    pub x: Tensor,
    /// bank-function values at the collocation points, (M, N)
    pub f_at_x: Tensor,
}

impl NativeBatcher {
    pub fn new(
        m: usize,
        n: usize,
        q: usize,
        bank_size: usize,
        bank_grid: usize,
        rng: &mut Pcg64,
    ) -> Result<Self> {
        anyhow::ensure!(bank_size >= m, "bank_size {bank_size} < batch functions {m}");
        let sampler =
            GpSampler1d::new(Kernel::Rbf { length_scale: 0.2, variance: 1.0 }, bank_grid);
        let bank = FunctionBank::generate(&sampler, bank_size, rng)?;
        Ok(Self { bank, m, q, n, rng: rng.clone(), last_functions: Vec::new() })
    }

    pub fn bank(&self) -> &FunctionBank {
        &self.bank
    }

    pub fn last_functions(&self) -> &[usize] {
        &self.last_functions
    }

    /// Next (p, x, f(x)) batch.
    pub fn next_batch(&mut self) -> NativeBatch {
        self.last_functions = self.rng.choose(self.bank.len(), self.m);
        let mut pdata = Vec::with_capacity(self.m * self.q);
        for &fi in &self.last_functions {
            pdata.extend(self.bank.sensors(fi, self.q));
        }
        let p = Tensor::new(&[self.m, self.q], pdata);
        let xs = self.rng.uniforms_in(self.n, 0.0, 1.0);
        let mut fdata = Vec::with_capacity(self.m * self.n);
        for &fi in &self.last_functions {
            fdata.extend(self.bank.eval_many(fi, &xs));
        }
        let f_at_x = Tensor::new(&[self.m, self.n], fdata);
        let x = Tensor::new(&[self.n, 1], xs);
        NativeBatch { p, x, f_at_x }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::IoSpec;

    fn meta_for(kind: ProblemKind, schema: Vec<(&str, Vec<usize>)>) -> ArtifactMeta {
        ArtifactMeta {
            file: "f".into(),
            kind: "train".into(),
            problem: kind.name(),
            strategy: "zcs".into(),
            scale: "bench".into(),
            m: schema[0].1[0],
            n: schema[1].1[0],
            p_order: 2,
            n_params: 0,
            inputs: vec![IoSpec { name: "p".into(), shape: schema[0].1.clone(), dtype: "f32".into() }],
            outputs: vec![],
            param_layout: vec![],
            batch_schema: schema.into_iter().map(|(n, s)| (n.to_string(), s)).collect(),
        }
    }

    fn small_config() -> RunConfig {
        RunConfig { bank_size: 16, bank_grid: 32, ..Default::default() }
    }

    fn get_f32(arg: &RunArg) -> &HostTensor {
        match arg {
            RunArg::F32(t) => t,
            _ => panic!("expected f32 arg"),
        }
    }

    #[test]
    fn rd_batch_matches_schema_and_aux_is_consistent() {
        let kind = ProblemKind::ReactionDiffusion;
        let meta = meta_for(
            kind,
            vec![
                ("p", vec![4, 10]),
                ("x_in", vec![32, 2]),
                ("f_at_x", vec![4, 32]),
                ("x_ic", vec![8, 2]),
                ("x_bc", vec![8, 2]),
            ],
        );
        let mut rng = Pcg64::seeded(1);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 5);
        let x_in = get_f32(&batch[1]);
        let f_at_x = get_f32(&batch[2]);
        // aux field row 0 must equal bank eval of the chosen function at x
        let bank = b.bank().unwrap();
        let fi = b.last_functions()[0];
        for j in [0usize, 7, 31] {
            let x = x_in.data[2 * j] as f64;
            let want = bank.eval(fi, x) as f32;
            assert!((f_at_x.data[j] - want).abs() < 1e-6);
        }
        // IC points on t = 0, BC points on x in {0, 1}
        let x_ic = get_f32(&batch[3]);
        for r in 0..8 {
            assert_eq!(x_ic.data[2 * r + 1], 0.0);
        }
        let x_bc = get_f32(&batch[4]);
        for r in 0..8 {
            let x = x_bc.data[2 * r];
            assert!(x == 0.0 || x == 1.0);
        }
    }

    #[test]
    fn burgers_periodic_points_share_t() {
        let kind = ProblemKind::Burgers;
        let meta = meta_for(
            kind,
            vec![
                ("p", vec![3, 8]),
                ("x_in", vec![16, 2]),
                ("x_ic", vec![8, 2]),
                ("u0_ic", vec![3, 8]),
                ("x_left", vec![6, 2]),
                ("x_right", vec![6, 2]),
            ],
        );
        let mut rng = Pcg64::seeded(2);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        let batch = b.next_batch().unwrap();
        let left = get_f32(&batch[4]);
        let right = get_f32(&batch[5]);
        for r in 0..6 {
            assert_eq!(left.data[2 * r], 0.0);
            assert_eq!(right.data[2 * r], 1.0);
            assert_eq!(left.data[2 * r + 1], right.data[2 * r + 1]); // same t
        }
        // u0_ic row equals bank eval at x_ic abscissae
        let x_ic = get_f32(&batch[2]);
        let u0 = get_f32(&batch[3]);
        let bank = b.bank().unwrap();
        let fi = b.last_functions()[0];
        for j in 0..8 {
            let want = bank.eval(fi, x_ic.data[2 * j] as f64) as f32;
            assert!((u0.data[j] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn kirchhoff_coeffs_are_fresh_each_batch() {
        let kind = ProblemKind::Kirchhoff;
        let meta = meta_for(
            kind,
            vec![("p", vec![2, 9]), ("x_in", vec![8, 2]), ("x_bc", vec![8, 2])],
        );
        let mut rng = Pcg64::seeded(3);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        let b1 = b.next_batch().unwrap();
        let c1 = get_f32(&b1[0]).data.clone();
        let b2 = b.next_batch().unwrap();
        let c2 = get_f32(&b2[0]).data.clone();
        assert_ne!(c1, c2);
        // all four edges present in x_bc
        let bc = get_f32(&b1[2]);
        let on_edge = |r: usize| {
            let (x, y) = (bc.data[2 * r], bc.data[2 * r + 1]);
            x == 0.0 || x == 1.0 || y == 0.0 || y == 1.0
        };
        assert!((0..8).all(on_edge));
    }

    #[test]
    fn stokes_lid_mask_pins_lid_corners() {
        let kind = ProblemKind::Stokes;
        let meta = meta_for(
            kind,
            vec![
                ("p", vec![2, 8]),
                ("x_in", vec![8, 2]),
                ("x_lid", vec![4, 2]),
                ("u1_lid", vec![2, 4]),
                ("x_bot", vec![4, 2]),
                ("x_lr", vec![4, 2]),
            ],
        );
        let mut rng = Pcg64::seeded(4);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        let batch = b.next_batch().unwrap();
        let lid = get_f32(&batch[2]);
        for r in 0..4 {
            assert_eq!(lid.data[2 * r + 1], 1.0); // y = 1
        }
        // sensor rows vanish at the endpoints thanks to the mask
        let p = get_f32(&batch[0]);
        assert!(p.data[0].abs() < 1e-6); // sensor at x = 0
        assert!(p.data[7].abs() < 1e-6); // sensor at x = 1
        let lr = get_f32(&batch[5]);
        for r in 0..4 {
            let x = lr.data[2 * r];
            assert!(x == 0.0 || x == 1.0);
        }
    }

    #[test]
    fn native_batcher_shapes_and_consistency() {
        let mut rng = Pcg64::seeded(9);
        let (m, n, q) = (3, 12, 7);
        let mut b = NativeBatcher::new(m, n, q, 16, 32, &mut rng).unwrap();
        let batch = b.next_batch();
        assert_eq!(batch.p.shape(), &[m, q]);
        assert_eq!(batch.x.shape(), &[n, 1]);
        assert_eq!(batch.f_at_x.shape(), &[m, n]);
        // f_at_x row 0 is the bank eval of the chosen function at x
        let fi = b.last_functions()[0];
        for j in [0usize, 5, 11] {
            let want = b.bank().eval(fi, batch.x.data()[j]);
            assert!((batch.f_at_x.at2(0, j) - want).abs() < 1e-12);
        }
        // batches differ
        let batch2 = b.next_batch();
        assert_ne!(batch.x.data(), batch2.x.data());
    }

    #[test]
    fn native_batcher_rejects_small_bank() {
        let mut rng = Pcg64::seeded(10);
        assert!(NativeBatcher::new(8, 4, 4, 4, 16, &mut rng).is_err());
    }

    #[test]
    fn function_subset_changes_between_batches() {
        let kind = ProblemKind::ReactionDiffusion;
        let meta = meta_for(
            kind,
            vec![
                ("p", vec![4, 10]),
                ("x_in", vec![8, 2]),
                ("f_at_x", vec![4, 8]),
                ("x_ic", vec![4, 2]),
                ("x_bc", vec![4, 2]),
            ],
        );
        let mut rng = Pcg64::seeded(5);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        b.next_batch().unwrap();
        let f1 = b.last_functions().to_vec();
        b.next_batch().unwrap();
        let f2 = b.last_functions().to_vec();
        assert_ne!(f1, f2);
    }
}
