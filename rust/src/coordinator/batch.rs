//! Per-problem batch assembly (the paper's "Inputs" stage).
//!
//! Every step the coordinator resamples collocation points uniformly over
//! the domain, picks a fresh subset of input functions from the GP bank, and
//! interpolates whatever auxiliary fields the physics loss needs at exactly
//! those points.  Array order and shapes follow the manifest `batch_schema`
//! byte for byte -- the Rust/Python contract is positional.

use crate::config::RunConfig;
use crate::pde::{residual::residual_for, ProblemKind};
use crate::rng::Pcg64;
use crate::runtime::{ArtifactMeta, HostTensor, RunArg};
use crate::sampler::{
    boundary_points_2d, interior_columns_2d, interior_points_2d, Edge, FunctionBank, GpSampler1d,
};
use crate::solvers::KirchhoffSolver;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};

/// Stateful batch generator bound to one (problem, artifact) pair.
pub struct Batcher {
    kind: ProblemKind,
    m: usize,
    q: usize,
    schema: Vec<(String, Vec<usize>)>,
    /// GP input-function bank (None for Kirchhoff / coefficient problems)
    bank: Option<FunctionBank>,
    rng: Pcg64,
    /// function indices used by the most recent batch
    last_functions: Vec<usize>,
    /// most recent Kirchhoff coefficient draw (row-major M x Q)
    last_coeffs: Vec<f64>,
}

impl Batcher {
    pub fn new(
        kind: ProblemKind,
        meta: &ArtifactMeta,
        config: &RunConfig,
        rng: &mut Pcg64,
    ) -> Result<Self> {
        let (p_name, p_shape) = &meta.batch_schema[0];
        if p_name != "p" {
            bail!("batch schema must start with 'p', got {p_name}");
        }
        let (m, q) = (p_shape[0], p_shape[1]);
        let bank = match kind.function_prior() {
            Some(kernel) => {
                let sampler = GpSampler1d::new(kernel, config.bank_grid);
                let mut bank = FunctionBank::generate(&sampler, config.bank_size, rng)?;
                if kind.lid_mask() {
                    bank = bank.masked(|x| x * (1.0 - x));
                }
                Some(bank)
            }
            None => None,
        };
        Ok(Self {
            kind,
            m,
            q,
            schema: meta.batch_schema.clone(),
            bank,
            rng: rng.clone(),
            last_functions: Vec::new(),
            last_coeffs: Vec::new(),
        })
    }

    pub fn bank(&self) -> Option<&FunctionBank> {
        self.bank.as_ref()
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn q(&self) -> usize {
        self.q
    }

    pub fn last_functions(&self) -> &[usize] {
        &self.last_functions
    }

    pub fn last_coeffs(&self) -> &[f64] {
        &self.last_coeffs
    }

    /// Build the sensor matrix `p` for an explicit set of bank functions.
    pub fn sensors_for(&self, functions: &[usize]) -> HostTensor {
        let bank = self.bank.as_ref().expect("problem has a function bank");
        let mut data = Vec::with_capacity(functions.len() * self.q);
        for &fi in functions {
            data.extend(bank.sensors(fi, self.q).iter().map(|&v| v as f32));
        }
        HostTensor::new(vec![functions.len(), self.q], data)
    }

    /// Next training batch, in manifest order.
    pub fn next_batch(&mut self) -> Result<Vec<RunArg>> {
        // 1. pick the function subset for this batch
        match self.kind {
            ProblemKind::Kirchhoff => {
                self.last_coeffs = self.rng.normals(self.m * self.q);
            }
            _ => {
                let bank_len = self.bank.as_ref().map(|b| b.len()).unwrap_or(0);
                self.last_functions = self.rng.choose(bank_len, self.m.min(bank_len));
            }
        }
        // 2. interior points first (several aux fields need them)
        let x_in_shape = self
            .schema
            .iter()
            .find(|(n, _)| n == "x_in")
            .map(|(_, s)| s.clone())
            .expect("schema has x_in");
        let x_in = interior_points_2d(&mut self.rng, x_in_shape[0], (0.0, 1.0), (0.0, 1.0));

        let mut out = Vec::with_capacity(self.schema.len());
        // shared temp: paired t-values for periodic BCs
        let mut periodic_ts: Vec<f64> = Vec::new();
        let mut lid_xs: Vec<f64> = Vec::new();
        for (name, shape) in self.schema.clone() {
            let arg: HostTensor = match name.as_str() {
                "p" => match self.kind {
                    ProblemKind::Kirchhoff => HostTensor::from_f64(
                        vec![self.m, self.q],
                        &self.last_coeffs,
                    ),
                    _ => self.sensors_for(&self.last_functions.clone()),
                },
                "x_in" => HostTensor::from_f64(x_in.shape().to_vec(), x_in.data()),
                // rd: source f evaluated at the interior x-coordinates
                "f_at_x" => self.aux_at_dim0(&x_in, shape[1]),
                // t = 0 line
                "x_ic" => {
                    let (pts, _free) = boundary_points_2d(&mut self.rng, shape[0], Edge::D1Lo);
                    HostTensor::from_f64(pts.shape().to_vec(), pts.data())
                }
                // burgers: u0 at the IC points (must match x_ic's abscissae):
                // regenerate deterministically from the previous entry
                "u0_ic" => {
                    // x_ic was pushed immediately before u0_ic by schema order
                    let prev = out.last().expect("x_ic precedes u0_ic");
                    let RunArg::F32(x_ic) = prev else { unreachable!() };
                    let xs: Vec<f64> =
                        (0..x_ic.dims[0]).map(|r| x_ic.data[2 * r] as f64).collect();
                    self.aux_at_xs(&xs, shape[1])
                }
                "x_bc" => self.dirichlet_edges(shape[0]),
                "x_left" => {
                    periodic_ts = self.rng.uniforms_in(shape[0], 0.0, 1.0);
                    let mut data = Vec::with_capacity(2 * shape[0]);
                    for &t in &periodic_ts {
                        data.push(0.0f32);
                        data.push(t as f32);
                    }
                    HostTensor::new(shape.clone(), data)
                }
                "x_right" => {
                    let mut data = Vec::with_capacity(2 * shape[0]);
                    for &t in &periodic_ts {
                        data.push(1.0f32);
                        data.push(t as f32);
                    }
                    HostTensor::new(shape.clone(), data)
                }
                "x_lid" => {
                    let (pts, free) = boundary_points_2d(&mut self.rng, shape[0], Edge::D1Hi);
                    lid_xs = free;
                    HostTensor::from_f64(pts.shape().to_vec(), pts.data())
                }
                "u1_lid" => self.aux_at_xs(&lid_xs, shape[1]),
                "x_bot" => {
                    let (pts, _) = boundary_points_2d(&mut self.rng, shape[0], Edge::D1Lo);
                    HostTensor::from_f64(pts.shape().to_vec(), pts.data())
                }
                "x_lr" => self.lr_edges(shape[0]),
                other => bail!("unknown batch array {other:?} in schema"),
            };
            if arg.dims != shape {
                bail!("batch array {name}: built {:?}, schema wants {:?}", arg.dims, shape);
            }
            out.push(RunArg::F32(arg));
        }
        Ok(out)
    }

    /// Aux field: bank functions evaluated at the dim-0 coordinate of `pts`.
    fn aux_at_dim0(&self, pts: &Tensor, n: usize) -> HostTensor {
        let xs: Vec<f64> = (0..n).map(|r| pts.at2(r, 0)).collect();
        self.aux_at_xs(&xs, n)
    }

    /// Aux field: bank functions evaluated at explicit abscissae, (M, n).
    fn aux_at_xs(&self, xs: &[f64], n: usize) -> HostTensor {
        assert_eq!(xs.len(), n);
        let bank = self.bank.as_ref().expect("problem has a function bank");
        let mut data = Vec::with_capacity(self.m * n);
        for &fi in &self.last_functions {
            data.extend(bank.eval_many(fi, xs).iter().map(|&v| v as f32));
        }
        HostTensor::new(vec![self.m, n], data)
    }

    /// Dirichlet boundary points: rd -> x = 0/1 edges; kirchhoff -> all four.
    fn dirichlet_edges(&mut self, n: usize) -> HostTensor {
        let edges: &[Edge] = match self.kind {
            ProblemKind::ReactionDiffusion => &[Edge::D0Lo, Edge::D0Hi],
            _ => &[Edge::D0Lo, Edge::D0Hi, Edge::D1Lo, Edge::D1Hi],
        };
        let mut data = Vec::with_capacity(2 * n);
        for i in 0..n {
            let edge = edges[i % edges.len()];
            let (pts, _) = boundary_points_2d(&mut self.rng, 1, edge);
            data.push(pts.data()[0] as f32);
            data.push(pts.data()[1] as f32);
        }
        HostTensor::new(vec![n, 2], data)
    }

    /// Left/right wall points for Stokes.
    fn lr_edges(&mut self, n: usize) -> HostTensor {
        let mut data = Vec::with_capacity(2 * n);
        for i in 0..n {
            let edge = if i % 2 == 0 { Edge::D0Lo } else { Edge::D0Hi };
            let (pts, _) = boundary_points_2d(&mut self.rng, 1, edge);
            data.push(pts.data()[0] as f32);
            data.push(pts.data()[1] as f32);
        }
        HostTensor::new(vec![n, 2], data)
    }
}

/// Sizes of one native batch (the native analogue of an artifact's
/// `batch_schema` dimensions).
#[derive(Clone, Copy, Debug)]
pub struct PdeBatchSpec {
    /// functions per batch (the paper's M)
    pub m: usize,
    /// interior collocation points per batch (the paper's N)
    pub n_in: usize,
    /// points per boundary/initial block
    pub n_bc: usize,
    /// branch sensors (the paper's Q)
    pub q: usize,
    /// GP function-bank size (ignored for Kirchhoff)
    pub bank_size: usize,
    /// GP bank grid resolution
    pub bank_grid: usize,
}

/// One native batch: the sensor matrix plus the named feeds of
/// [`crate::pde::residual::BuiltProblem::feeds`], in schema order.
pub struct PdeBatch {
    /// sensor matrix (M, Q): GP samples at the sensors, or Kirchhoff's
    /// i.i.d. normal load coefficients
    pub p: Tensor,
    pub feeds: Vec<(String, Tensor)>,
}

impl PdeBatch {
    /// An empty batch for [`PdeBatcher::fill_batch`] to populate; after
    /// the first fill every subsequent fill reuses the allocations.
    pub fn empty() -> Self {
        Self { p: Tensor::zeros(&[0]), feeds: Vec::new() }
    }

    /// Copy the function-dimension rows `rows = (r0, r1)` of this batch
    /// into `shard`: the sensor matrix and every function-rowed feed
    /// ([`is_function_rowed`]) keep only those rows, while point feeds
    /// (shared by all functions) are copied whole.  Overwrites in place
    /// like [`PdeBatcher::fill_batch`] -- after the first call nothing
    /// reallocates.
    ///
    /// Sharding happens *after* a full draw, so the batcher's random
    /// sequence is exactly the unsharded one, and concatenating the
    /// shards of a lane partition reproduces this batch bit-for-bit --
    /// the property that makes data-parallel replicas trajectory-exact
    /// (pinned by `function_shards_concatenate_to_the_unsharded_batch`).
    pub fn shard_into(&self, rows: (usize, usize), shard: &mut PdeBatch) {
        let (r0, r1) = rows;
        let m = self.p.shape()[0];
        assert!(r0 < r1 && r1 <= m, "bad function-row range {r0}..{r1} of {m}");
        copy_rows(&self.p, r0, r1, &mut shard.p);
        for (i, (name, src)) in self.feeds.iter().enumerate() {
            if shard.feeds.len() == i {
                shard.feeds.push((name.clone(), Tensor::zeros(&[0])));
            }
            let (have, dst) = &mut shard.feeds[i];
            assert_eq!(have, name, "feed order changed between shards");
            if is_function_rowed(name) {
                debug_assert_eq!(src.shape()[0], m, "function-rowed feed has M rows");
                copy_rows(src, r0, r1, dst);
            } else {
                dst.reset(src.shape()).copy_from_slice(src.data());
            }
        }
        assert_eq!(shard.feeds.len(), self.feeds.len(), "stale extra feeds in shard");
    }
}

/// Whether a named feed's rows are input functions (the paper's M
/// dimension): exactly the auxiliary fields the residual layer registers
/// per function -- everything else is a point block shared by every
/// function.  This is what [`PdeBatch::shard_into`] splits.
pub fn is_function_rowed(name: &str) -> bool {
    matches!(name, "in.f" | "in.q" | "ic.u0")
}

/// Rows `r0..r1` of a row-major `(rows, width)` block copied into `dst`
/// (reset to `(r1 - r0, width)`, reusing its allocation).
fn copy_rows(src: &Tensor, r0: usize, r1: usize, dst: &mut Tensor) {
    let w = src.shape()[1];
    dst.reset(&[r1 - r0, w]).copy_from_slice(&src.data()[r0 * w..r1 * w]);
}

/// Batch generator for the *native* engine (no artifacts, no PJRT): every
/// step it picks a fresh function subset from the GP bank (or draws fresh
/// Kirchhoff coefficients), resamples collocation points via `sampler/`,
/// and interpolates whatever auxiliary fields the problem's
/// [`crate::pde::residual::PdeResidual`] declared.  The native counterpart
/// of [`Batcher`], feeding compiled [`crate::autodiff::Program`]s in
/// [`crate::coordinator::native::NativeTrainer`].
pub struct PdeBatcher {
    kind: ProblemKind,
    spec: PdeBatchSpec,
    /// GP input-function bank (None for Kirchhoff / coefficient problems)
    bank: Option<FunctionBank>,
    /// sqrt(q) sine modes per direction (Kirchhoff only)
    kirchhoff_modes: usize,
    rng: Pcg64,
    last_functions: Vec<usize>,
    last_coeffs: Vec<f64>,
    /// sensor abscissae (lazily built linspace over [0, 1])
    sensor_xs: Vec<f64>,
    /// scratch columns reused across [`PdeBatcher::fill_batch`] calls so
    /// the steady state allocates nothing
    scratch_x: Vec<f64>,
    scratch_y: Vec<f64>,
}

/// Write cursor over a [`PdeBatch`]'s named feeds: reuses the tensor at
/// each position (growing the vec only on the first fill), so batch
/// buffers are overwritten in place step after step.
struct FeedCursor<'a> {
    feeds: &'a mut Vec<(String, Tensor)>,
    idx: usize,
}

impl FeedCursor<'_> {
    /// The mutable payload of the next feed, reset to `shape`; the caller
    /// must overwrite every element.
    fn next(&mut self, name: &str, shape: &[usize]) -> &mut [f64] {
        if self.idx == self.feeds.len() {
            self.feeds.push((name.to_string(), Tensor::zeros(&[0])));
        }
        let (have, t) = &mut self.feeds[self.idx];
        assert_eq!(have.as_str(), name, "feed order changed between fills");
        self.idx += 1;
        t.reset(shape)
    }

    /// A feed that is a single column of `values`.
    fn col(&mut self, name: &str, values: &[f64]) {
        self.next(name, &[values.len(), 1]).copy_from_slice(values);
    }

    /// A constant-valued column feed.
    fn const_col(&mut self, name: &str, n: usize, v: f64) {
        self.next(name, &[n, 1]).fill(v);
    }
}

impl PdeBatcher {
    pub fn new(kind: ProblemKind, spec: PdeBatchSpec, rng: &mut Pcg64) -> Result<Self> {
        ensure!(
            residual_for(kind).is_some(),
            "problem {:?} has no native residual; native problems: antiderivative, \
             reaction_diffusion, burgers, kirchhoff",
            kind.name()
        );
        ensure!(spec.m >= 1 && spec.n_in >= 1 && spec.n_bc >= 1 && spec.q >= 1, "empty batch spec");
        let bank = match kind.function_prior() {
            Some(kernel) => {
                ensure!(
                    spec.bank_size >= spec.m,
                    "bank_size {} < batch functions {}",
                    spec.bank_size,
                    spec.m
                );
                let sampler = GpSampler1d::new(kernel, spec.bank_grid);
                Some(FunctionBank::generate(&sampler, spec.bank_size, rng)?)
            }
            None => None,
        };
        let kirchhoff_modes = if kind == ProblemKind::Kirchhoff {
            let r = (spec.q as f64).sqrt().round() as usize;
            ensure!(
                r * r == spec.q,
                "kirchhoff sensors are an R x R sine-mode grid; q = {} is not square",
                spec.q
            );
            r
        } else {
            0
        };
        Ok(Self {
            kind,
            spec,
            bank,
            kirchhoff_modes,
            rng: rng.clone(),
            last_functions: Vec::new(),
            last_coeffs: Vec::new(),
            sensor_xs: Vec::new(),
            scratch_x: Vec::new(),
            scratch_y: Vec::new(),
        })
    }

    pub fn bank(&self) -> Option<&FunctionBank> {
        self.bank.as_ref()
    }

    /// Capture the draw state for checkpointing.  The bank is fully
    /// determined by the construction config (it is generated *before*
    /// the batcher's own generator is cloned off), so a resume rebuilds
    /// the batcher from the same config and restores only this snapshot.
    pub fn rng_snapshot(&self) -> crate::rng::Pcg64Snapshot {
        self.rng.snapshot()
    }

    /// Restore the draw state captured by [`PdeBatcher::rng_snapshot`]:
    /// the subsequent batch stream is bit-identical to the one the
    /// snapshotted batcher would have produced.
    pub fn rng_restore(&mut self, snap: &crate::rng::Pcg64Snapshot) {
        self.rng.restore(snap);
    }

    pub fn last_functions(&self) -> &[usize] {
        &self.last_functions
    }

    pub fn last_coeffs(&self) -> &[f64] {
        &self.last_coeffs
    }

    /// Next batch, feeds in the residual layer's registration order.
    /// Allocates a fresh [`PdeBatch`]; steady-state callers should hold
    /// one batch and refill it with [`PdeBatcher::fill_batch`].
    pub fn next_batch(&mut self) -> PdeBatch {
        let mut batch = PdeBatch::empty();
        self.fill_batch(&mut batch);
        batch
    }

    /// Overwrite `batch` in place with the next draw -- no feed tensor is
    /// reallocated after the first fill, and the random sequence is
    /// identical to repeated [`PdeBatcher::next_batch`] calls.
    pub fn fill_batch(&mut self, batch: &mut PdeBatch) {
        let PdeBatchSpec { m, n_in, n_bc, q, .. } = self.spec;
        // -- sensor matrix p
        match self.kind {
            ProblemKind::Kirchhoff => {
                self.last_coeffs.resize(m * q, 0.0);
                self.rng.fill_normals(&mut self.last_coeffs);
                batch.p.reset(&[m, q]).copy_from_slice(&self.last_coeffs);
            }
            _ => {
                let bank = self.bank.as_ref().expect("problem has a function bank");
                self.last_functions = self.rng.choose(bank.len(), m);
                if self.sensor_xs.len() != q {
                    self.sensor_xs = Tensor::linspace(0.0, 1.0, q).into_data();
                }
                let p = batch.p.reset(&[m, q]);
                for (i, &fi) in self.last_functions.iter().enumerate() {
                    for (j, &x) in self.sensor_xs.iter().enumerate() {
                        p[i * q + j] = bank.eval(fi, x);
                    }
                }
            }
        }

        let mut cur = FeedCursor { feeds: &mut batch.feeds, idx: 0 };
        match self.kind {
            ProblemKind::Antiderivative => {
                self.scratch_x.resize(n_in, 0.0);
                self.rng.fill_uniforms_in(&mut self.scratch_x, 0.0, 1.0);
                cur.col("in.x0", &self.scratch_x);
                bank_rows(
                    self.bank.as_ref(),
                    &self.last_functions,
                    &self.scratch_x,
                    cur.next("in.f", &[m, n_in]),
                );
            }
            ProblemKind::ReactionDiffusion => {
                fill_interior(&mut self.rng, &mut self.scratch_x, &mut self.scratch_y, n_in);
                cur.col("in.x0", &self.scratch_x);
                cur.col("in.x1", &self.scratch_y);
                // the source f is time-independent: evaluate at the x column
                bank_rows(
                    self.bank.as_ref(),
                    &self.last_functions,
                    &self.scratch_x,
                    cur.next("in.f", &[m, n_in]),
                );
                self.scratch_x.resize(n_bc, 0.0);
                self.rng.fill_uniforms_in(&mut self.scratch_x, 0.0, 1.0);
                cur.col("ic.x0", &self.scratch_x);
                cur.const_col("ic.x1", n_bc, 0.0);
                let walls = cur.next("bc.x0", &[n_bc, 1]);
                for (i, w) in walls.iter_mut().enumerate() {
                    *w = (i % 2) as f64;
                }
                self.rng.fill_uniforms_in(&mut self.scratch_x, 0.0, 1.0);
                cur.col("bc.x1", &self.scratch_x);
            }
            ProblemKind::Burgers => {
                fill_interior(&mut self.rng, &mut self.scratch_x, &mut self.scratch_y, n_in);
                cur.col("in.x0", &self.scratch_x);
                cur.col("in.x1", &self.scratch_y);
                self.scratch_x.resize(n_bc, 0.0);
                self.rng.fill_uniforms_in(&mut self.scratch_x, 0.0, 1.0);
                cur.col("ic.x0", &self.scratch_x);
                cur.const_col("ic.x1", n_bc, 0.0);
                bank_rows(
                    self.bank.as_ref(),
                    &self.last_functions,
                    &self.scratch_x,
                    cur.next("ic.u0", &[m, n_bc]),
                );
                // periodic pairs share their t coordinates
                self.rng.fill_uniforms_in(&mut self.scratch_x, 0.0, 1.0);
                cur.const_col("left.x0", n_bc, 0.0);
                cur.col("left.x1", &self.scratch_x);
                cur.const_col("right.x0", n_bc, 1.0);
                cur.col("right.x1", &self.scratch_x);
            }
            ProblemKind::Kirchhoff => {
                fill_interior(&mut self.rng, &mut self.scratch_x, &mut self.scratch_y, n_in);
                cur.col("in.x0", &self.scratch_x);
                cur.col("in.x1", &self.scratch_y);
                kirchhoff_load(
                    self.kirchhoff_modes,
                    &self.last_coeffs,
                    (m, q),
                    &self.scratch_x,
                    &self.scratch_y,
                    cur.next("in.q", &[m, n_in]),
                );
                // points cycling the four unit-square edges
                self.scratch_x.resize(n_bc, 0.0);
                self.scratch_y.resize(n_bc, 0.0);
                for i in 0..n_bc {
                    let s = self.rng.uniform();
                    let (x, y) = match i % 4 {
                        0 => (0.0, s),
                        1 => (1.0, s),
                        2 => (s, 0.0),
                        _ => (s, 1.0),
                    };
                    self.scratch_x[i] = x;
                    self.scratch_y[i] = y;
                }
                cur.col("bnd.x0", &self.scratch_x);
                cur.col("bnd.x1", &self.scratch_y);
                // moment blocks: u_xx on the x-walls, u_yy on the y-walls
                let mx = cur.next("mx.x0", &[n_bc, 1]);
                for (i, w) in mx.iter_mut().enumerate() {
                    *w = (i % 2) as f64;
                }
                self.scratch_x.resize(n_bc, 0.0);
                self.rng.fill_uniforms_in(&mut self.scratch_x, 0.0, 1.0);
                cur.col("mx.x1", &self.scratch_x);
                self.rng.fill_uniforms_in(&mut self.scratch_x, 0.0, 1.0);
                cur.col("my.x0", &self.scratch_x);
                let my = cur.next("my.x1", &[n_bc, 1]);
                for (i, w) in my.iter_mut().enumerate() {
                    *w = (i % 2) as f64;
                }
            }
            other => unreachable!("PdeBatcher::new rejects {other:?}"),
        }
        let filled = cur.idx;
        assert_eq!(filled, batch.feeds.len(), "stale extra feeds in batch");
    }
}

/// Draw `n` interior points into the two scratch columns --
/// [`interior_columns_2d`] is the same sampler [`interior_points_2d`]
/// delegates to, so the native and artifact batchers can never drift.
fn fill_interior(rng: &mut Pcg64, xs: &mut Vec<f64>, ys: &mut Vec<f64>, n: usize) {
    interior_columns_2d(rng, n, (0.0, 1.0), (0.0, 1.0), xs, ys);
}

/// Bank functions evaluated at explicit abscissae into an (M, len) row
/// block.
fn bank_rows(bank: Option<&FunctionBank>, functions: &[usize], xs: &[f64], out: &mut [f64]) {
    let bank = bank.expect("problem has a function bank");
    let n = xs.len();
    assert_eq!(out.len(), functions.len() * n);
    for (i, &fi) in functions.iter().enumerate() {
        for (j, &x) in xs.iter().enumerate() {
            out[i * n + j] = bank.eval(fi, x);
        }
    }
}

/// The Kirchhoff load `q(x, y)` synthesised from the current coefficient
/// draw at the given points, into an (M, len) row block.
fn kirchhoff_load(
    modes: usize,
    coeffs: &[f64],
    (m, q): (usize, usize),
    xs: &[f64],
    ys: &[f64],
    out: &mut [f64],
) {
    // rigidity never enters the load series; keep the shared constant
    // anyway so every Kirchhoff site reads the same value
    let rigidity = ProblemKind::Kirchhoff.constant("D_flex").expect("paper constant");
    let solver = KirchhoffSolver { rigidity, r_modes: modes, s_modes: modes };
    let pts: Vec<(f64, f64)> = xs.iter().zip(ys).map(|(&x, &y)| (x, y)).collect();
    let n = xs.len();
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let c = &coeffs[i * q..(i + 1) * q];
        out[i * n..(i + 1) * n].copy_from_slice(&solver.source_at(c, &pts));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::IoSpec;

    fn meta_for(kind: ProblemKind, schema: Vec<(&str, Vec<usize>)>) -> ArtifactMeta {
        ArtifactMeta {
            file: "f".into(),
            kind: "train".into(),
            problem: kind.name(),
            strategy: "zcs".into(),
            scale: "bench".into(),
            m: schema[0].1[0],
            n: schema[1].1[0],
            p_order: 2,
            n_params: 0,
            inputs: vec![IoSpec { name: "p".into(), shape: schema[0].1.clone(), dtype: "f32".into() }],
            outputs: vec![],
            param_layout: vec![],
            batch_schema: schema.into_iter().map(|(n, s)| (n.to_string(), s)).collect(),
        }
    }

    fn small_config() -> RunConfig {
        RunConfig { bank_size: 16, bank_grid: 32, ..Default::default() }
    }

    fn get_f32(arg: &RunArg) -> &HostTensor {
        match arg {
            RunArg::F32(t) => t,
            _ => panic!("expected f32 arg"),
        }
    }

    #[test]
    fn rd_batch_matches_schema_and_aux_is_consistent() {
        let kind = ProblemKind::ReactionDiffusion;
        let meta = meta_for(
            kind,
            vec![
                ("p", vec![4, 10]),
                ("x_in", vec![32, 2]),
                ("f_at_x", vec![4, 32]),
                ("x_ic", vec![8, 2]),
                ("x_bc", vec![8, 2]),
            ],
        );
        let mut rng = Pcg64::seeded(1);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 5);
        let x_in = get_f32(&batch[1]);
        let f_at_x = get_f32(&batch[2]);
        // aux field row 0 must equal bank eval of the chosen function at x
        let bank = b.bank().unwrap();
        let fi = b.last_functions()[0];
        for j in [0usize, 7, 31] {
            let x = x_in.data[2 * j] as f64;
            let want = bank.eval(fi, x) as f32;
            assert!((f_at_x.data[j] - want).abs() < 1e-6);
        }
        // IC points on t = 0, BC points on x in {0, 1}
        let x_ic = get_f32(&batch[3]);
        for r in 0..8 {
            assert_eq!(x_ic.data[2 * r + 1], 0.0);
        }
        let x_bc = get_f32(&batch[4]);
        for r in 0..8 {
            let x = x_bc.data[2 * r];
            assert!(x == 0.0 || x == 1.0);
        }
    }

    #[test]
    fn burgers_periodic_points_share_t() {
        let kind = ProblemKind::Burgers;
        let meta = meta_for(
            kind,
            vec![
                ("p", vec![3, 8]),
                ("x_in", vec![16, 2]),
                ("x_ic", vec![8, 2]),
                ("u0_ic", vec![3, 8]),
                ("x_left", vec![6, 2]),
                ("x_right", vec![6, 2]),
            ],
        );
        let mut rng = Pcg64::seeded(2);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        let batch = b.next_batch().unwrap();
        let left = get_f32(&batch[4]);
        let right = get_f32(&batch[5]);
        for r in 0..6 {
            assert_eq!(left.data[2 * r], 0.0);
            assert_eq!(right.data[2 * r], 1.0);
            assert_eq!(left.data[2 * r + 1], right.data[2 * r + 1]); // same t
        }
        // u0_ic row equals bank eval at x_ic abscissae
        let x_ic = get_f32(&batch[2]);
        let u0 = get_f32(&batch[3]);
        let bank = b.bank().unwrap();
        let fi = b.last_functions()[0];
        for j in 0..8 {
            let want = bank.eval(fi, x_ic.data[2 * j] as f64) as f32;
            assert!((u0.data[j] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn kirchhoff_coeffs_are_fresh_each_batch() {
        let kind = ProblemKind::Kirchhoff;
        let meta = meta_for(
            kind,
            vec![("p", vec![2, 9]), ("x_in", vec![8, 2]), ("x_bc", vec![8, 2])],
        );
        let mut rng = Pcg64::seeded(3);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        let b1 = b.next_batch().unwrap();
        let c1 = get_f32(&b1[0]).data.clone();
        let b2 = b.next_batch().unwrap();
        let c2 = get_f32(&b2[0]).data.clone();
        assert_ne!(c1, c2);
        // all four edges present in x_bc
        let bc = get_f32(&b1[2]);
        let on_edge = |r: usize| {
            let (x, y) = (bc.data[2 * r], bc.data[2 * r + 1]);
            x == 0.0 || x == 1.0 || y == 0.0 || y == 1.0
        };
        assert!((0..8).all(on_edge));
    }

    #[test]
    fn stokes_lid_mask_pins_lid_corners() {
        let kind = ProblemKind::Stokes;
        let meta = meta_for(
            kind,
            vec![
                ("p", vec![2, 8]),
                ("x_in", vec![8, 2]),
                ("x_lid", vec![4, 2]),
                ("u1_lid", vec![2, 4]),
                ("x_bot", vec![4, 2]),
                ("x_lr", vec![4, 2]),
            ],
        );
        let mut rng = Pcg64::seeded(4);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        let batch = b.next_batch().unwrap();
        let lid = get_f32(&batch[2]);
        for r in 0..4 {
            assert_eq!(lid.data[2 * r + 1], 1.0); // y = 1
        }
        // sensor rows vanish at the endpoints thanks to the mask
        let p = get_f32(&batch[0]);
        assert!(p.data[0].abs() < 1e-6); // sensor at x = 0
        assert!(p.data[7].abs() < 1e-6); // sensor at x = 1
        let lr = get_f32(&batch[5]);
        for r in 0..4 {
            let x = lr.data[2 * r];
            assert!(x == 0.0 || x == 1.0);
        }
    }

    fn spec(m: usize, n_in: usize, n_bc: usize, q: usize) -> PdeBatchSpec {
        PdeBatchSpec { m, n_in, n_bc, q, bank_size: 16, bank_grid: 32 }
    }

    fn feed<'a>(batch: &'a PdeBatch, name: &str) -> &'a Tensor {
        &batch.feeds.iter().find(|(n, _)| n == name).unwrap_or_else(|| panic!("{name}")).1
    }

    #[test]
    fn pde_batcher_antiderivative_shapes_and_consistency() {
        let mut rng = Pcg64::seeded(9);
        let (m, n, q) = (3, 12, 7);
        let mut b =
            PdeBatcher::new(ProblemKind::Antiderivative, spec(m, n, 4, q), &mut rng).unwrap();
        let batch = b.next_batch();
        assert_eq!(batch.p.shape(), &[m, q]);
        let x = feed(&batch, "in.x0");
        let f = feed(&batch, "in.f");
        assert_eq!(x.shape(), &[n, 1]);
        assert_eq!(f.shape(), &[m, n]);
        // f row 0 is the bank eval of the chosen function at x
        let fi = b.last_functions()[0];
        for j in [0usize, 5, 11] {
            let want = b.bank().unwrap().eval(fi, x.data()[j]);
            assert!((f.at2(0, j) - want).abs() < 1e-12);
        }
        // batches differ
        let batch2 = b.next_batch();
        assert_ne!(x.data(), feed(&batch2, "in.x0").data());
    }

    #[test]
    fn pde_batcher_rd_points_respect_the_domain() {
        let mut rng = Pcg64::seeded(12);
        let mut b =
            PdeBatcher::new(ProblemKind::ReactionDiffusion, spec(2, 8, 6, 5), &mut rng).unwrap();
        let batch = b.next_batch();
        // IC points sit on t = 0, BC points on x in {0, 1}
        assert!(feed(&batch, "ic.x1").data().iter().all(|&t| t == 0.0));
        assert!(feed(&batch, "bc.x0").data().iter().all(|&x| x == 0.0 || x == 1.0));
        // source rows are the bank functions at the interior x column
        let xs = feed(&batch, "in.x0");
        let f = feed(&batch, "in.f");
        let fi = b.last_functions()[1];
        for j in [0usize, 7] {
            let want = b.bank().unwrap().eval(fi, xs.data()[j]);
            assert!((f.at2(1, j) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn pde_batcher_burgers_periodic_pairs_share_t() {
        let mut rng = Pcg64::seeded(13);
        let mut b = PdeBatcher::new(ProblemKind::Burgers, spec(2, 8, 6, 5), &mut rng).unwrap();
        let batch = b.next_batch();
        assert!(feed(&batch, "left.x0").data().iter().all(|&x| x == 0.0));
        assert!(feed(&batch, "right.x0").data().iter().all(|&x| x == 1.0));
        assert_eq!(feed(&batch, "left.x1").data(), feed(&batch, "right.x1").data());
        // u0 rows equal bank evals at the IC abscissae
        let icx = feed(&batch, "ic.x0");
        let u0 = feed(&batch, "ic.u0");
        let fi = b.last_functions()[0];
        for j in 0..6 {
            let want = b.bank().unwrap().eval(fi, icx.data()[j]);
            assert!((u0.at2(0, j) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn pde_batcher_kirchhoff_load_matches_the_solver_series() {
        let mut rng = Pcg64::seeded(14);
        let mut b = PdeBatcher::new(ProblemKind::Kirchhoff, spec(2, 6, 8, 9), &mut rng).unwrap();
        let batch = b.next_batch();
        assert_eq!(batch.p.shape(), &[2, 9]);
        // load row equals the solver's source series for the same coeffs
        let xs = feed(&batch, "in.x0");
        let ys = feed(&batch, "in.x1");
        let qf = feed(&batch, "in.q");
        let solver = KirchhoffSolver { rigidity: 0.01, r_modes: 3, s_modes: 3 };
        let want = solver.source_at(&b.last_coeffs()[..9], &[(xs.data()[2], ys.data()[2])]);
        assert!((qf.at2(0, 2) - want[0]).abs() < 1e-12);
        // all edge points are on an edge; moment blocks pin the right wall
        let bx = feed(&batch, "bnd.x0");
        let by = feed(&batch, "bnd.x1");
        for i in 0..8 {
            let (x, y) = (bx.data()[i], by.data()[i]);
            assert!(x == 0.0 || x == 1.0 || y == 0.0 || y == 1.0);
        }
        assert!(feed(&batch, "mx.x0").data().iter().all(|&x| x == 0.0 || x == 1.0));
        assert!(feed(&batch, "my.x1").data().iter().all(|&y| y == 0.0 || y == 1.0));
        // fresh coefficients each batch
        let c1 = b.last_coeffs().to_vec();
        b.next_batch();
        assert_ne!(c1, b.last_coeffs());
    }

    #[test]
    fn function_shards_concatenate_to_the_unsharded_batch() {
        use crate::pde::residual::{lane_bounds, lane_count};
        // m = 5 over 4 lanes exercises the M % N != 0 remainder (lane row
        // counts 1/1/1/2); three steps prove sharding leaves the
        // batcher's draw sequence untouched
        let m = 5;
        for kind in
            [ProblemKind::Antiderivative, ProblemKind::Burgers, ProblemKind::Kirchhoff]
        {
            let q = if kind == ProblemKind::Kirchhoff { 9 } else { 6 };
            let mut rng = Pcg64::seeded(21);
            let mut b = PdeBatcher::new(kind, spec(m, 8, 6, q), &mut rng).unwrap();
            let mut rng2 = Pcg64::seeded(21);
            let mut unsharded = PdeBatcher::new(kind, spec(m, 8, 6, q), &mut rng2).unwrap();
            let n_lanes = lane_count(m);
            let mut shards: Vec<PdeBatch> = (0..n_lanes).map(|_| PdeBatch::empty()).collect();
            for _step in 0..3 {
                let full = b.next_batch();
                let want = unsharded.next_batch();
                assert_eq!(full.p.data(), want.p.data(), "draw sequence drifted");
                for (l, s) in shards.iter_mut().enumerate() {
                    full.shard_into(lane_bounds(m, n_lanes, l), s);
                }
                let cat: Vec<f64> =
                    shards.iter().flat_map(|s| s.p.data().iter().copied()).collect();
                assert_eq!(cat, full.p.data(), "sensor rows");
                for (i, (name, src)) in full.feeds.iter().enumerate() {
                    if is_function_rowed(name) {
                        let cat: Vec<f64> = shards
                            .iter()
                            .flat_map(|s| s.feeds[i].1.data().iter().copied())
                            .collect();
                        assert_eq!(cat, src.data(), "{name}");
                    } else {
                        for s in &shards {
                            assert_eq!(s.feeds[i].1.data(), src.data(), "{name}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pde_batcher_rejects_bad_specs() {
        let mut rng = Pcg64::seeded(10);
        // bank smaller than the batch
        assert!(PdeBatcher::new(ProblemKind::Antiderivative, spec(20, 4, 4, 4), &mut rng).is_err());
        // kirchhoff wants a square sensor count
        assert!(PdeBatcher::new(ProblemKind::Kirchhoff, spec(2, 4, 4, 8), &mut rng).is_err());
        // stokes has no native residual yet
        assert!(PdeBatcher::new(ProblemKind::Stokes, spec(2, 4, 4, 4), &mut rng).is_err());
    }

    #[test]
    fn function_subset_changes_between_batches() {
        let kind = ProblemKind::ReactionDiffusion;
        let meta = meta_for(
            kind,
            vec![
                ("p", vec![4, 10]),
                ("x_in", vec![8, 2]),
                ("f_at_x", vec![4, 8]),
                ("x_ic", vec![4, 2]),
                ("x_bc", vec![4, 2]),
            ],
        );
        let mut rng = Pcg64::seeded(5);
        let mut b = Batcher::new(kind, &meta, &small_config(), &mut rng).unwrap();
        b.next_batch().unwrap();
        let f1 = b.last_functions().to_vec();
        b.next_batch().unwrap();
        let f2 = b.last_functions().to_vec();
        assert_ne!(f1, f2);
    }
}
