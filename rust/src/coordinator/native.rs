//! Native training loop: compiled [`Program`]s executed inside the train
//! loop, no artifacts or PJRT anywhere.
//!
//! The physics comes from the native residual layer
//! ([`crate::pde::residual`]): `zcs ntrain --problem <name>` trains any
//! problem with an implemented [`PdeResidual`] -- the antiderivative toy,
//! reaction-diffusion, Burgers, and the fourth-order Kirchhoff-Love plate
//! -- under any of the paper's three AD strategies (eq. 4 FuncLoop, eq. 5
//! DataVect, or the eq. 10 ZCS z-chain).  The loss gradient w.r.t. the
//! weights differentiates *through* the chosen strategy, exactly like the
//! paper's PDE losses.
//!
//! The entire step -- forward, strategy derivatives, residual + boundary
//! losses, weight gradients, **and the optimizer** -- is built as one
//! [`Graph`], lowered **once** by [`Program::compile`] +
//! [`Program::attach_optimizer`], and then executed every step by a
//! persistent [`Executor`] (compile-once / run-many).  On the default
//! *resident* path the weights (and Adam moments) live inside the
//! executor: each step feeds batch data only, the in-Program
//! [`UpdateRule`] instructions walk the weights in place straight from
//! the gradients' arena slots, and only three loss scalars are read back
//! -- no gradient clones, no host-side weight math, zero steady-state
//! heap traffic.  Both [`Optimizer::Sgd`] and bias-corrected
//! [`Optimizer::Adam`] (what the paper's DeepXDE baselines run) are
//! supported, on the resident and the feed-based fallback path alike;
//! resident trajectories bit-match the feed-based ones
//! (`rust/tests/resident_step.rs`).
//!
//! With more than one function per batch the trainer steps the
//! data-parallel replica layer ([`super::replica`]) instead of a single
//! program: the function dimension is decomposed into canonical lane
//! blocks, each replica executor owns a contiguous run of lanes on its
//! own kernel pool (`--replicas` / `ZCS_REPLICAS` splits the thread
//! budget), and gradients fold through the deterministic fixed-order
//! in-Program all-reduce -- so N-replica trajectories bit-match
//! single-replica runs, losses and final weights alike
//! (`rust/tests/replica_train.rs`).
//!
//! Batches come from [`PdeBatcher`], matched to the residual layer's feed
//! schema by name.  [`NativeReport`] carries the same staged timings as
//! the PJRT [`super::TrainReport`], plus the compiler's
//! [`ProgramReport`], so `zcs ntrain` and the benches can put
//! strategy-vs-strategy and per-problem numbers side by side;
//! [`NativeTrainer::validate`] closes the loop against the independent
//! reference solvers in [`crate::solvers`].
//!
//! [`PdeResidual`]: crate::pde::residual::PdeResidual
//! [`Graph`]: crate::autodiff::Graph
//! [`UpdateRule`]: crate::autodiff::UpdateRule

use crate::autodiff::zcs_demo::Strategy;
use crate::autodiff::{Executor, NodeId, ProfileReport, Program, SchedMode, UpdateRule};
use crate::coordinator::batch::{PdeBatch, PdeBatchSpec, PdeBatcher};
use crate::coordinator::checkpoint::{self, CheckpointMeta, TrainCheckpoint};
use crate::coordinator::error::{panic_text, TrainError};
use crate::coordinator::replica::ReplicaSet;
use crate::hlostats::{analyze_program, ProgramReport};
use crate::pde::residual::{
    build_forward, build_training_problem, init_problem_weights, BlockSizes, NetDims,
};
use crate::pde::ProblemKind;
use crate::rng::{Pcg64, Pcg64Snapshot};
use crate::sampler::{FunctionBank, GpSampler1d};
use crate::solvers::{BurgersSolver, KirchhoffSolver, ReactionDiffusionSolver};
use crate::tensor::simd::{SimdLevel, SimdMode};
use crate::tensor::Tensor;
use crate::util::env::{env_fault, FaultCell, FaultKind, SanitizeMode};
use anyhow::{anyhow, ensure, Context, Result};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// The optimizer a native run applies each step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optimizer {
    /// plain gradient descent, `w -= lr * g`
    Sgd,
    /// bias-corrected Adam with the paper-standard constants
    /// ([`Optimizer::BETA1`], [`Optimizer::BETA2`], [`Optimizer::EPS`])
    Adam,
}

impl Optimizer {
    pub const BETA1: f64 = 0.9;
    pub const BETA2: f64 = 0.999;
    pub const EPS: f64 = 1e-8;

    /// Case-insensitive parse with a choice-listing error.
    pub fn parse(name: &str) -> Result<Optimizer, String> {
        match name.to_ascii_lowercase().as_str() {
            "sgd" => Ok(Optimizer::Sgd),
            "adam" => Ok(Optimizer::Adam),
            other => Err(format!("unknown optimizer {other:?}; choices: sgd, adam")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::Sgd => "sgd",
            Optimizer::Adam => "adam",
        }
    }

    /// The in-Program update rule at a given learning rate.
    pub fn rule(&self, lr: f64) -> UpdateRule {
        match self {
            Optimizer::Sgd => UpdateRule::Sgd { lr },
            Optimizer::Adam => UpdateRule::Adam {
                lr,
                beta1: Self::BETA1,
                beta2: Self::BETA2,
                eps: Self::EPS,
            },
        }
    }
}

/// Configuration of a native training run.
#[derive(Clone, Debug)]
pub struct NativeRunConfig {
    pub problem: ProblemKind,
    pub strategy: Strategy,
    /// functions per batch (the paper's M)
    pub m: usize,
    /// interior collocation points per batch (the paper's N)
    pub n: usize,
    /// points per boundary/initial block
    pub n_bc: usize,
    /// branch sensors (the paper's Q)
    pub q: usize,
    /// hidden width of both MLPs
    pub hidden: usize,
    /// latent combine dimension (the DeepONet K)
    pub k: usize,
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub bank_size: usize,
    pub bank_grid: usize,
    pub log_every: usize,
    /// kernel threads for the executor (0 = auto: `ZCS_THREADS`, else 1);
    /// results are bit-identical for any value
    pub threads: usize,
    /// data-parallel replica executors sharding the function dimension
    /// (0 = auto: `ZCS_REPLICAS`, else 1); clamped to the lane count and
    /// forced to 1 on the feed-based fallback.  The thread budget is
    /// split across replicas and trajectories are bit-identical for any
    /// value ([`super::replica`])
    pub replicas: usize,
    /// the per-step weight update (SGD or Adam)
    pub optimizer: Optimizer,
    /// keep weights + optimizer state resident in the executor and step
    /// them with in-Program update instructions (the default); `false`
    /// falls back to feeding weights per step and updating host-side --
    /// same trajectory bit for bit, more per-step traffic
    pub resident: bool,
    /// instruction schedule: out-of-order graph claiming (the default)
    /// or the strict serial loop; results are bit-identical either way
    pub schedule: SchedMode,
    /// kernel SIMD mode (off / fixed width / auto-detect); trajectories
    /// are bit-identical across widths for every order-preserving kernel,
    /// and reproducible per width for the reassociating reductions
    pub simd: SimdMode,
    /// overlap batch generation with step execution on a producer thread
    /// (double-buffered; identical draw sequence, so trajectories
    /// bit-match the synchronous loop)
    pub pipeline: bool,
    /// collect a per-opcode / per-wavefront wall-time profile
    /// ([`NativeReport::profile`]); zero overhead when off
    pub profile: bool,
    /// write a v2 checkpoint every N completed steps (0 = off; requires
    /// [`NativeRunConfig::checkpoint_path`])
    pub checkpoint_every: usize,
    /// where periodic and final v2 checkpoints go (atomic tmp + fsync +
    /// rename); also the rollback target when a run dies mid-flight
    pub checkpoint_path: Option<String>,
    /// resume bit-exactly from a v2 checkpoint written by an identically
    /// configured run (trajectory-determining fields are validated;
    /// thread/replica/SIMD knobs may differ freely)
    pub resume_from: Option<String>,
    /// deterministic fault injector (tests pass a local cell here;
    /// `None` falls back to the process-wide `ZCS_FAULT` cell)
    pub fault: Option<Arc<FaultCell>>,
    /// correctness layer: `Off` (default) pays nothing, `Static` verifies
    /// every compiled Program, `Full` additionally arms the executor's
    /// runtime tripwires (shadow-arena race stamps, per-instruction
    /// NaN/Inf scan) and the stall watchdogs.  Defaults to `ZCS_SANITIZE`
    pub sanitize: SanitizeMode,
    /// stall watchdog deadline in milliseconds, used when `sanitize` is
    /// `Full` (replica barrier + step completion).  Defaults to
    /// `ZCS_STALL_MS` (30000)
    pub stall_ms: u64,
}

impl Default for NativeRunConfig {
    fn default() -> Self {
        Self {
            problem: ProblemKind::Antiderivative,
            strategy: Strategy::Zcs,
            m: 4,
            n: 16,
            n_bc: 8,
            q: 8,
            hidden: 16,
            k: 8,
            steps: 200,
            lr: 1e-2,
            seed: 20230923,
            bank_size: 64,
            bank_grid: 128,
            log_every: 20,
            threads: 0,
            replicas: 0,
            optimizer: Optimizer::Sgd,
            resident: true,
            schedule: SchedMode::from_env(),
            simd: SimdMode::from_env(),
            pipeline: false,
            profile: false,
            checkpoint_every: 0,
            checkpoint_path: None,
            resume_from: None,
            fault: None,
            sanitize: crate::util::env::env_sanitize(),
            stall_ms: crate::util::env::env_stall_ms(),
        }
    }
}

impl NativeRunConfig {
    /// A problem-appropriate learning rate (the Kirchhoff load keeps its
    /// loss orders of magnitude above the others, so first-order updates
    /// need a smaller step there).
    pub fn default_lr(problem: ProblemKind) -> f64 {
        match problem {
            ProblemKind::Kirchhoff => 2e-3,
            _ => 1e-2,
        }
    }
}

/// One logged point of the native loss curve.
#[derive(Clone, Copy, Debug)]
pub struct NativePoint {
    pub step: usize,
    pub loss: f64,
    pub loss_pde: f64,
    pub loss_bc: f64,
}

/// Outcome of a native run.
#[derive(Clone, Debug)]
pub struct NativeReport {
    pub curve: Vec<NativePoint>,
    pub final_loss: f64,
    pub steps: usize,
    /// batch generation time (the paper's "Inputs" stage)
    pub input_time: Duration,
    /// time inside compiled-program execution
    pub step_time: Duration,
    /// graph build + compile time (paid once)
    pub compile_time: Duration,
    /// compiler statistics of the step program
    pub program: ProgramReport,
    /// the optimizer applied each step
    pub optimizer: Optimizer,
    /// bytes of executor-resident training state (weights + moments);
    /// 0 on the feed-based fallback path
    pub resident_state_bytes: u64,
    /// the instruction schedule the run executed under
    pub schedule: SchedMode,
    /// the resolved kernel lane width the run executed under
    pub simd: SimdLevel,
    /// whether batch generation overlapped execution on a producer thread
    pub pipelined: bool,
    /// data-parallel replica executors the run stepped on (1 unless the
    /// run was replicated)
    pub replicas: usize,
    /// lane blocks in the canonical function-dimension decomposition
    /// (1 on the single-program `m == 1` path)
    pub lanes: usize,
    /// per-opcode / per-wavefront profile, when requested
    /// ([`NativeRunConfig::profile`]); on a replicated run this is the
    /// lead replica's profile
    pub profile: Option<ProfileReport>,
    /// profiles of replicas 1.. on a profiled replicated run (the lead
    /// replica's is [`NativeReport::profile`]); empty otherwise
    pub replica_profiles: Vec<ProfileReport>,
}

impl NativeReport {
    /// Paper-style "time per 1000 batches" in seconds.
    pub fn sec_per_1000(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.step_time.as_secs_f64() / self.steps as f64 * 1000.0
    }

    /// Training throughput in steps per second (excluding input
    /// generation, like [`NativeReport::sec_per_1000`]).
    pub fn steps_per_sec(&self) -> f64 {
        let s = self.step_time.as_secs_f64();
        if s <= 0.0 {
            return 0.0;
        }
        self.steps as f64 / s
    }
}

/// Relative-L2 validation of the trained operator on held-out inputs.
#[derive(Clone, Copy, Debug)]
pub struct NativeValidation {
    pub rel_l2: f64,
    pub n_functions: usize,
    pub n_points: usize,
}

/// Where one program input comes from on the per-step fast path.
#[derive(Clone, Copy, Debug)]
enum FeedSrc {
    /// index into the trainer's host weight vector (feed-based fallback
    /// only: resident programs read weights from executor state instead)
    Weight(usize),
    /// the batch's sensor matrix `p`
    Sensor,
    /// index into the batch's named feeds
    Feed(usize),
    /// index into the constant extra inputs (ZCS `z` and `a`)
    Extra(usize),
}

/// The native training orchestrator: one compiled step program + a
/// persistent executor.  On the resident path (the default) the optimizer
/// runs *inside* the program and the whole step is one executor call; the
/// feed-based fallback keeps weights host-side and applies the same
/// optimizer kernels after each run -- bit-identical trajectories either
/// way while the loss stays finite.  (On the step that diverges the paths
/// differ: the resident update has already run inside the program when
/// the non-finite loss is detected, while the fallback checks first and
/// leaves its host weights untouched; [`NativeTrainer::run`] stops on the
/// error either way.)
pub struct NativeTrainer {
    pub config: NativeRunConfig,
    batcher: PdeBatcher,
    engine: Engine,
    coord_dim: usize,
    compile_time: Duration,
    /// completed steps already in the restored state (0 on a fresh run);
    /// [`NativeTrainer::run`] executes `start_step..steps`
    start_step: usize,
}

/// The stepping machinery behind a [`NativeTrainer`]: one program over
/// the whole batch when there is a single function, the lane-sharded
/// replica layer otherwise (even a 1-replica set, so the decomposition
/// -- and therefore the trajectory -- never depends on the replica
/// count, only on the problem).
enum Engine {
    Single(SingleEngine),
    Replicated(ReplicaSet),
}

/// The legacy single-program engine (`m == 1`): one compiled step
/// program + one executor over the whole function batch.
struct SingleEngine {
    program: Program,
    exec: Executor,
    /// wb (q,h), wb2 (h,k), wt (d,h), wt2 (h,k) -- fallback path only;
    /// resident weights live in the executor's state slots
    weights: Vec<Tensor>,
    /// host-side Adam (m, v) pairs -- fallback path only
    moments: Vec<(Tensor, Tensor)>,
    /// host-side optimizer timestep -- fallback path only
    host_t: u64,
    n_weights: usize,
    resident: bool,
    weight_ids: Vec<NodeId>,
    p_id: NodeId,
    /// named batch feeds, in the residual layer's schema order
    feeds: Vec<(String, NodeId)>,
    extra_inputs: Vec<(NodeId, Tensor)>,
    /// one source per [`Program::inputs`] entry, resolved once at build
    /// time so stepping never rebuilds a feed `HashMap`
    feed_plan: Vec<FeedSrc>,
    /// reusable per-step feed buffer (raw pointers so its capacity
    /// persists across steps; re-borrowed inside [`StepEngine::step`])
    feed_scratch: Vec<*const Tensor>,
    /// deterministic fault injector shared with the executor
    fault: Option<Arc<FaultCell>>,
}

impl SingleEngine {
    fn new(config: &NativeRunConfig) -> Result<(Self, usize, Duration)> {
        let t0 = Instant::now();
        let built = build_training_problem(
            config.problem,
            config.strategy,
            config.m,
            config.q,
            config.hidden,
            config.k,
            BlockSizes { n_in: config.n, n_bc: config.n_bc },
        )?;
        let mut program = Program::compile(&built.graph, &built.outputs);
        if config.resident {
            program = program.attach_optimizer(&built.weight_ids, config.optimizer.rule(config.lr));
        }
        if config.sanitize.verify() {
            // debug builds and ZCS_SANITIZE already verified at compile;
            // this catches a config-level opt-in (e.g. `--sanitize`) in
            // release builds and surfaces the report as a typed Result
            // instead of a panic
            program
                .verify()
                .map_err(|e| anyhow!("step program failed verification: {e}"))?;
        }
        let compile_time = t0.elapsed();

        let weights = init_problem_weights(&built, config.seed);
        let n_weights = weights.len();

        // resolve every program input to its source once, so the hot loop
        // never hashes node ids or rebuilds a feed map (resident programs
        // have no weight inputs: those became executor state)
        let mut src_of: HashMap<NodeId, FeedSrc> = HashMap::new();
        for (i, id) in built.weight_ids.iter().enumerate() {
            src_of.insert(*id, FeedSrc::Weight(i));
        }
        src_of.insert(built.p, FeedSrc::Sensor);
        for (i, (_, id)) in built.feeds.iter().enumerate() {
            src_of.insert(*id, FeedSrc::Feed(i));
        }
        for (i, (id, _)) in built.extra_inputs.iter().enumerate() {
            src_of.insert(*id, FeedSrc::Extra(i));
        }
        let feed_plan: Vec<FeedSrc> = program
            .inputs
            .iter()
            .map(|id| {
                src_of
                    .get(id)
                    .copied()
                    .ok_or_else(|| anyhow!("step program wants unknown input node {id}"))
            })
            .collect::<Result<_>>()?;

        let threads = if config.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            config.threads
        };
        let mut exec =
            Executor::with_threads(threads).with_sched(config.schedule).with_simd(config.simd);
        exec.set_sanitize(config.sanitize.dynamic());
        if config.profile {
            exec.enable_profiling();
        }
        if let Some(cell) = &config.fault {
            // resident NaN injection happens inside the executor's
            // update pass; the fallback's happens in [`StepEngine::step`]
            exec.arm_fault(Arc::clone(cell));
        }
        let resident = config.resident;
        let (weights, moments) = if resident {
            exec.bind_states(&program, weights);
            (Vec::new(), Vec::new())
        } else {
            let moments = match config.optimizer {
                Optimizer::Adam => weights
                    .iter()
                    .map(|w| (Tensor::zeros(w.shape()), Tensor::zeros(w.shape())))
                    .collect(),
                Optimizer::Sgd => Vec::new(),
            };
            (weights, moments)
        };
        let engine = Self {
            program,
            exec,
            weights,
            moments,
            host_t: 0,
            n_weights,
            resident,
            weight_ids: built.weight_ids,
            p_id: built.p,
            feeds: built.feeds,
            extra_inputs: built.extra_inputs,
            feed_plan,
            feed_scratch: Vec::new(),
            fault: config.fault.clone(),
        };
        Ok((engine, built.coord_dim, compile_time))
    }

    /// Snapshot the training state for a checkpoint: weights, Adam
    /// `(m, v)` pairs (empty for SGD), and the optimizer timestep.
    fn export_states(&self) -> (Vec<Tensor>, Vec<(Tensor, Tensor)>, u64) {
        if self.resident {
            let states = self.exec.states();
            let weights = states[..self.n_weights].to_vec();
            let mut moments = Vec::new();
            if states.len() > self.n_weights {
                for i in 0..self.n_weights {
                    moments.push((
                        states[self.n_weights + 2 * i].clone(),
                        states[self.n_weights + 2 * i + 1].clone(),
                    ));
                }
            }
            (weights, moments, self.exec.opt_steps())
        } else {
            (self.weights.clone(), self.moments.clone(), self.host_t)
        }
    }

    /// Restore a checkpointed training state (see
    /// [`crate::coordinator::replica::ReplicaSet::restore_states`]).
    fn restore_states(
        &mut self,
        weights: &[Tensor],
        moments: &[(Tensor, Tensor)],
        opt_t: u64,
    ) -> Result<()> {
        ensure!(
            weights.len() == self.n_weights,
            "checkpoint has {} weights, this problem has {}",
            weights.len(),
            self.n_weights
        );
        if self.resident {
            // executor-resident layout: weights first, then interleaved
            // (m, v) pairs in weight order
            let mut full: Vec<Tensor> = weights.to_vec();
            for (m, v) in moments {
                full.push(m.clone());
                full.push(v.clone());
            }
            ensure!(
                full.len() == self.exec.states().len(),
                "checkpoint carries {} state tensors, the program wants {}",
                full.len(),
                self.exec.states().len()
            );
            self.exec.restore_states(&full, opt_t);
        } else {
            ensure!(
                moments.len() == self.moments.len(),
                "checkpoint has {} adam moment pairs, this optimizer wants {}",
                moments.len(),
                self.moments.len()
            );
            self.weights = weights.to_vec();
            self.moments = moments.to_vec();
            self.host_t = opt_t;
        }
        Ok(())
    }

    /// Borrow the per-step stepping view (see [`NativeTrainer::split`]).
    fn step_engine(&mut self, lr: f64, optimizer: Optimizer) -> StepEngine<'_> {
        let Self {
            program,
            exec,
            weights,
            moments,
            host_t,
            n_weights,
            resident,
            feeds,
            extra_inputs,
            feed_plan,
            feed_scratch,
            fault,
            ..
        } = self;
        StepEngine {
            program: &*program,
            exec,
            weights,
            moments,
            host_t,
            n_weights: *n_weights,
            resident: *resident,
            lr,
            optimizer,
            feeds: feeds.as_slice(),
            extra_inputs: extra_inputs.as_slice(),
            feed_plan: feed_plan.as_slice(),
            feed_scratch,
            fault: fault.clone(),
        }
    }
}

impl NativeTrainer {
    pub fn new(config: NativeRunConfig) -> Result<Self> {
        let mut config = config;
        ensure!(config.m >= 1 && config.n >= 1 && config.q >= 1, "empty problem");
        ensure!(
            config.checkpoint_every == 0 || config.checkpoint_path.is_some(),
            "checkpoint_every wants a checkpoint path"
        );
        if config.fault.is_none() {
            // the process-wide ZCS_FAULT cell, unless a test armed its own
            config.fault = env_fault();
        }
        let mut batch_rng = Pcg64::new(config.seed, 1);
        let batcher = PdeBatcher::new(
            config.problem,
            PdeBatchSpec {
                m: config.m,
                n_in: config.n,
                n_bc: config.n_bc,
                q: config.q,
                bank_size: config.bank_size,
                bank_grid: config.bank_grid,
            },
            &mut batch_rng,
        )?;
        let (engine, coord_dim, compile_time) = if config.m == 1 {
            let (engine, coord_dim, compile_time) = SingleEngine::new(&config)?;
            (Engine::Single(engine), coord_dim, compile_time)
        } else {
            let set = ReplicaSet::new(&config)?;
            let (coord_dim, compile_time) = (set.coord_dim(), set.compile_time());
            (Engine::Replicated(set), coord_dim, compile_time)
        };
        let mut trainer =
            Self { config, batcher, engine, coord_dim, compile_time, start_step: 0 };
        if let Some(path) = trainer.config.resume_from.clone() {
            let ckpt = checkpoint::load_train(&path)?;
            trainer
                .restore_checkpoint(&ckpt)
                .with_context(|| format!("resuming from {path:?}"))?;
        }
        Ok(trainer)
    }

    /// The trajectory-determining metadata of this run, as stored in (and
    /// validated against) v2 checkpoints.
    pub fn checkpoint_meta(&self) -> CheckpointMeta {
        CheckpointMeta {
            problem: self.config.problem.name(),
            strategy: self.config.strategy.name().to_string(),
            optimizer: self.config.optimizer.name().to_string(),
            m: self.config.m as u64,
            n: self.config.n as u64,
            n_bc: self.config.n_bc as u64,
            q: self.config.q as u64,
            hidden: self.config.hidden as u64,
            k: self.config.k as u64,
            lr: self.config.lr,
            seed: self.config.seed,
            bank_size: self.config.bank_size as u64,
            bank_grid: self.config.bank_grid as u64,
            replicas: self.replicas() as u64,
            threads: self.threads() as u64,
            simd: self.simd_level().name().to_string(),
        }
    }

    /// The resolved kernel SIMD level of the run's executor(s).
    fn simd_level(&self) -> SimdLevel {
        match &self.engine {
            Engine::Single(e) => e.exec.simd(),
            Engine::Replicated(r) => r.simd(),
        }
    }

    /// Snapshot the full training state as a v2 checkpoint recording
    /// `completed` finished steps.  The batcher's draw state is captured
    /// as of the last batch drawn, so a resume generates exactly the
    /// batches the uninterrupted run would have.
    pub fn export_checkpoint(&self, completed: u64) -> TrainCheckpoint {
        let (weights, moments, opt_t) = match &self.engine {
            Engine::Single(e) => e.export_states(),
            Engine::Replicated(r) => r.export_states(),
        };
        TrainCheckpoint {
            meta: self.checkpoint_meta(),
            step: completed,
            opt_t,
            rng: self.batcher.rng_snapshot(),
            weights,
            moments,
        }
    }

    /// Restore a v2 checkpoint into the engine and batcher (meta is
    /// validated field by field first), without touching the step window.
    fn apply_checkpoint(&mut self, ckpt: &TrainCheckpoint) -> Result<()> {
        ckpt.meta.validate(&self.checkpoint_meta()).map_err(anyhow::Error::from)?;
        match &mut self.engine {
            Engine::Single(e) => e.restore_states(&ckpt.weights, &ckpt.moments, ckpt.opt_t)?,
            Engine::Replicated(r) => {
                r.restore_states(&ckpt.weights, &ckpt.moments, ckpt.opt_t)?
            }
        }
        self.batcher.rng_restore(&ckpt.rng);
        Ok(())
    }

    /// Resume from a v2 checkpoint: validate the metadata, restore the
    /// weights / moments / optimizer clock / batcher draw state, and make
    /// [`NativeTrainer::run`] continue from the checkpointed step.  The
    /// resumed trajectory is bit-identical to the uninterrupted run
    /// (`rust/tests/checkpoint_resume.rs`).
    pub fn restore_checkpoint(&mut self, ckpt: &TrainCheckpoint) -> Result<()> {
        ensure!(
            (ckpt.step as usize) < self.config.steps,
            "checkpoint already has {} completed steps, the run is only {} steps; \
             nothing to resume",
            ckpt.step,
            self.config.steps
        );
        self.apply_checkpoint(ckpt)?;
        self.start_step = ckpt.step as usize;
        Ok(())
    }

    /// Compiler statistics of the step program (the lead replica's, on a
    /// replicated run -- replica programs differ only in lane ownership).
    pub fn program_report(&self) -> ProgramReport {
        match &self.engine {
            Engine::Single(e) => analyze_program(&e.program),
            Engine::Replicated(r) => r.program_report(),
        }
    }

    /// Graph build + compile time (paid once at construction; summed over
    /// all replica programs on a replicated run).
    pub fn compile_time(&self) -> Duration {
        self.compile_time
    }

    /// Current weights (wb, wb2, wt, wt2) -- read from the executor's
    /// resident state slots on the resident path, from the host copies on
    /// the fallback path.
    pub fn weights(&self) -> &[Tensor] {
        match &self.engine {
            Engine::Single(e) => {
                if e.resident {
                    &e.exec.states()[..e.n_weights]
                } else {
                    &e.weights
                }
            }
            Engine::Replicated(r) => r.weights(),
        }
    }

    /// Whether weights + optimizer state live inside the executor(s).
    pub fn resident(&self) -> bool {
        match &self.engine {
            Engine::Single(e) => e.resident,
            Engine::Replicated(r) => r.resident(),
        }
    }

    /// Bytes of executor-resident training state (0 on the fallback
    /// path); per replica, on a replicated run.
    pub fn resident_state_bytes(&self) -> u64 {
        match &self.engine {
            Engine::Single(e) => e.program.resident_state_bytes(),
            Engine::Replicated(r) => r.resident_state_bytes(),
        }
    }

    /// Total kernel-thread budget of the run: the executor's pool on the
    /// single-program path, the budget split across the replica pools on
    /// a replicated run.
    pub fn threads(&self) -> usize {
        match &self.engine {
            Engine::Single(e) => e.exec.threads(),
            Engine::Replicated(r) => r.threads(),
        }
    }

    /// Graph id of the sensor-matrix leaf `p` (useful for feeding the
    /// step program directly in tests and tools); `None` on a replicated
    /// run, where every lane block owns its own sensor leaf.
    pub fn sensor_node(&self) -> Option<NodeId> {
        match &self.engine {
            Engine::Single(e) => Some(e.p_id),
            Engine::Replicated(_) => None,
        }
    }

    /// Graph ids of the weight leaves, aligned with
    /// [`NativeTrainer::weights`]; `None` on a replicated run (each
    /// replica program has its own leaf ids).
    pub fn weight_nodes(&self) -> Option<&[NodeId]> {
        match &self.engine {
            Engine::Single(e) => Some(&e.weight_ids),
            Engine::Replicated(_) => None,
        }
    }

    /// Data-parallel replica executors stepping each batch.
    pub fn replicas(&self) -> usize {
        match &self.engine {
            Engine::Single(_) => 1,
            Engine::Replicated(r) => r.replicas(),
        }
    }

    /// Lane blocks in the function-dimension decomposition.
    pub fn lanes(&self) -> usize {
        match &self.engine {
            Engine::Single(_) => 1,
            Engine::Replicated(r) => r.lanes(),
        }
    }

    /// Draw the next batch from the trainer's own batcher (exposed so
    /// benches and tests can freeze a batch without re-building a second
    /// batcher from a hand-copied spec).
    pub fn next_batch(&mut self) -> PdeBatch {
        self.batcher.next_batch()
    }

    /// One optimizer step on one batch; returns (loss, loss_pde, loss_bc).
    ///
    /// Resident path: one [`Executor::run_scalars`] call per replica is
    /// the whole step -- batch references in, loss scalars out, weights
    /// and moments stepped in place inside the executor(s).  After warmup
    /// the loop performs no heap allocation at all (asserted by
    /// `rust/tests/resident_step.rs`).  Fallback path: weights are fed per
    /// step and updated host-side with the same optimizer kernels.
    ///
    /// A non-finite loss returns a typed [`TrainError::NonFinite`] on
    /// both paths, but note the asymmetry: the resident in-program update
    /// has run by the time the loss is read back, so diverged state is
    /// already in the executor, whereas the fallback bails before
    /// touching its host weights.  A worker panic surfaces as
    /// [`TrainError::WorkerPanic`] with the engine state untouched; an
    /// injected panic (`ZCS_FAULT=panic:K`) is transparently retried
    /// once, so trajectories under injection bit-match clean runs.
    pub fn step(&mut self, batch: &PdeBatch) -> Result<(f64, f64, f64)> {
        let fault = self.config.fault.clone();
        let (mut engine, _) = self.split();
        step_with_retry(&mut engine, batch, fault.as_deref())
    }

    /// Snapshot (weights, moments, opt_t) from the engine.
    fn export_states(&self) -> (Vec<Tensor>, Vec<(Tensor, Tensor)>, u64) {
        match &self.engine {
            Engine::Single(e) => e.export_states(),
            Engine::Replicated(r) => r.export_states(),
        }
    }

    /// Restore (weights, moments, opt_t) into the engine.
    fn restore_states_raw(
        &mut self,
        weights: &[Tensor],
        moments: &[(Tensor, Tensor)],
        opt_t: u64,
    ) -> Result<()> {
        match &mut self.engine {
            Engine::Single(e) => e.restore_states(weights, moments, opt_t),
            Engine::Replicated(r) => r.restore_states(weights, moments, opt_t),
        }
    }

    /// Split the trainer into the stepping engine and the batcher -- the
    /// disjoint borrows that let [`NativeTrainer::run`]'s pipelined mode
    /// fill batches on a producer thread while the main thread steps.
    fn split(&mut self) -> (StepRef<'_>, &mut PdeBatcher) {
        let engine = match &mut self.engine {
            Engine::Single(e) => {
                StepRef::Single(e.step_engine(self.config.lr, self.config.optimizer))
            }
            Engine::Replicated(r) => StepRef::Replicated(r),
        };
        (engine, &mut self.batcher)
    }

    /// Run the configured number of steps -- synchronously, or with batch
    /// generation overlapped on a producer thread when
    /// [`NativeRunConfig::pipeline`] is set.  The pipelined loop consumes
    /// the identical batch sequence (one batcher, drawn in order, one
    /// batch ahead at most), so both modes produce bit-identical
    /// trajectories; `rust/tests/sched_exec.rs` pins this.
    ///
    /// Crash safety: with [`NativeRunConfig::checkpoint_path`] set, a v2
    /// checkpoint is written atomically every
    /// [`NativeRunConfig::checkpoint_every`] steps and once at the end;
    /// if the run dies, the trainer's state is rolled back to the last
    /// good on-disk checkpoint before the error is returned.  Injected
    /// faults (`ZCS_FAULT`) are recovered transparently -- a panicked
    /// step is retried (engine state is untouched by a panic), a NaN'd
    /// gradient rolls back to an in-memory pre-fault snapshot -- and the
    /// recovered trajectory bit-matches a fault-free run.
    pub fn run(&mut self) -> Result<NativeReport> {
        match self.run_inner() {
            Ok(report) => Ok(report),
            Err(e) => {
                // leave the trainer at the last good checkpoint rather
                // than in the diverged / half-stepped state
                if let Some(path) = self.config.checkpoint_path.clone() {
                    if let Ok(ckpt) = checkpoint::load_train(&path) {
                        if self.apply_checkpoint(&ckpt).is_ok() {
                            self.start_step = (ckpt.step as usize).min(self.config.steps);
                            return Err(e.context(format!(
                                "training state rolled back to checkpoint {path:?} (step {})",
                                ckpt.step
                            )));
                        }
                    }
                }
                Err(e)
            }
        }
    }

    fn run_inner(&mut self) -> Result<NativeReport> {
        let steps = self.config.steps;
        let start = self.start_step;
        let log_every = self.config.log_every.max(1);
        let fault = self.config.fault.clone();
        let ckpt_every = self.config.checkpoint_every;
        let ckpt_path = self.config.checkpoint_path.clone();
        // a pending fault forces the synchronous loop: NaN rollback must
        // rewind the batcher, which the pipelined producer cannot do.
        // Determinism makes the switch invisible to the trajectory.
        let pipeline = self.config.pipeline && !fault.as_ref().is_some_and(|c| c.armed());
        let mut curve: Vec<NativePoint> = Vec::new();
        let mut input_time = Duration::ZERO;
        let mut step_time = Duration::ZERO;
        let mut last = (f64::NAN, f64::NAN, f64::NAN);
        let log = |curve: &mut Vec<NativePoint>, it: usize, last: (f64, f64, f64)| {
            if (it + 1) % log_every == 0 || it + 1 == steps {
                curve.push(NativePoint {
                    step: it + 1,
                    loss: last.0,
                    loss_pde: last.1,
                    loss_bc: last.2,
                });
            }
        };
        if !pipeline {
            // one batch's buffers, refilled in place every step
            let mut batch = PdeBatch::empty();
            // pre-step snapshot for transparent NaN recovery, refreshed
            // while the injected fault is still pending
            let mut rollback: Option<(
                usize,
                Vec<Tensor>,
                Vec<(Tensor, Tensor)>,
                u64,
                Pcg64Snapshot,
            )> = None;
            let mut it = start;
            while it < steps {
                if fault.as_ref().is_some_and(|c| c.expects(FaultKind::NanGrad)) {
                    let (w, m, t) = self.export_states();
                    rollback = Some((it, w, m, t, self.batcher.rng_snapshot()));
                }
                let t0 = Instant::now();
                self.batcher.fill_batch(&mut batch);
                input_time += t0.elapsed();
                let t1 = Instant::now();
                let stepped = {
                    let (mut engine, _) = self.split();
                    step_with_retry(&mut engine, &batch, fault.as_deref())
                };
                match stepped {
                    Ok(l) => last = l,
                    Err(e) => {
                        // transparent recovery from the injected NaN:
                        // restore the pre-fault snapshot (weights,
                        // moments, optimizer clock, batcher draw state)
                        // and re-run -- the recovered trajectory
                        // bit-matches a fault-free run
                        let injected_nan = fault.as_ref().is_some_and(|c| {
                            e.downcast_ref::<TrainError>()
                                .is_some_and(|te| matches!(te, TrainError::NonFinite { .. }))
                                && c.begin_recovery(FaultKind::NanGrad)
                        });
                        if injected_nan {
                            if let Some((rit, w, m, t, rng)) = rollback.take() {
                                self.restore_states_raw(&w, &m, t)?;
                                self.batcher.rng_restore(&rng);
                                curve.retain(|p| p.step <= rit);
                                it = rit;
                                continue;
                            }
                        }
                        return Err(e);
                    }
                }
                step_time += t1.elapsed();
                log(&mut curve, it, last);
                it += 1;
                if let Some(path) = &ckpt_path {
                    if ckpt_every > 0 && it % ckpt_every == 0 && it < steps {
                        let ckpt = self.export_checkpoint(it as u64);
                        checkpoint::save_train(path, &ckpt, fault.as_deref())?;
                    }
                }
            }
        } else {
            // double-buffered producer: two batches circulate, the
            // producer fills draw t+1 while the engine steps draw t
            let meta = self.checkpoint_meta();
            let (mut engine, batcher) = self.split();
            let pipe = BatchPipe::new();
            input_time = std::thread::scope(|s| -> Result<Duration> {
                // either side dying for any reason -- error return or
                // panic -- must close the pipe, or the other side
                // would block forever and the scope join would hang
                let _consumer_guard = PipeCloser(&pipe);
                let producer = s.spawn(|| {
                    let _guard = PipeCloser(&pipe);
                    let mut fill_time = Duration::ZERO;
                    let mut batch = PdeBatch::empty();
                    for _ in start..steps {
                        let t0 = Instant::now();
                        batcher.fill_batch(&mut batch);
                        fill_time += t0.elapsed();
                        // the post-draw snapshot travels with its batch:
                        // a checkpoint taken after stepping batch t
                        // resumes by drawing batch t+1
                        let snap = batcher.rng_snapshot();
                        match pipe.exchange(batch, snap) {
                            Some(next) => batch = next,
                            None => break, // consumer closed early
                        }
                    }
                    fill_time
                });
                let mut consumed: Result<()> = Ok(());
                for it in start..steps {
                    let Some((batch, rng_snap)) = pipe.take() else {
                        consumed = Err(anyhow!("batch producer stopped early"));
                        break;
                    };
                    let t1 = Instant::now();
                    match step_with_retry(&mut engine, &batch, fault.as_deref()) {
                        Ok(losses) => last = losses,
                        Err(e) => {
                            consumed = Err(e);
                            break;
                        }
                    }
                    step_time += t1.elapsed();
                    pipe.recycle(batch);
                    log(&mut curve, it, last);
                    if let Some(path) = &ckpt_path {
                        if ckpt_every > 0 && (it + 1) % ckpt_every == 0 && it + 1 < steps {
                            let (weights, moments, opt_t) = engine.export_states();
                            let ckpt = TrainCheckpoint {
                                meta: meta.clone(),
                                step: (it + 1) as u64,
                                opt_t,
                                rng: rng_snap,
                                weights,
                                moments,
                            };
                            if let Err(e) = checkpoint::save_train(path, &ckpt, fault.as_deref())
                            {
                                consumed = Err(e);
                                break;
                            }
                        }
                    }
                }
                // unblock the producer whether we finished or errored
                pipe.close();
                let fill_time = producer
                    .join()
                    .map_err(|_| anyhow!("batch producer thread panicked"))?;
                consumed?;
                Ok(fill_time)
            })?;
        }
        // final checkpoint: a finished run is itself a resumable state
        if let Some(path) = &ckpt_path {
            let ckpt = self.export_checkpoint(steps as u64);
            checkpoint::save_train(path, &ckpt, fault.as_deref())?;
        }
        let (schedule, simd, profile, replica_profiles) = match &mut self.engine {
            Engine::Single(e) => (e.exec.sched(), e.exec.simd(), e.exec.take_profile(), Vec::new()),
            Engine::Replicated(r) => {
                (r.sched(), r.simd(), r.take_profile(), r.take_replica_profiles())
            }
        };
        Ok(NativeReport {
            curve,
            final_loss: last.0,
            steps: steps - start,
            input_time,
            step_time,
            compile_time: self.compile_time,
            program: self.program_report(),
            optimizer: self.config.optimizer,
            resident_state_bytes: self.resident_state_bytes(),
            schedule,
            simd,
            pipelined: self.config.pipeline,
            replicas: self.replicas(),
            lanes: self.lanes(),
            profile,
            replica_profiles,
        })
    }

    /// Validate the trained operator against the problem's reference
    /// solver on `n_heldout` freshly sampled input functions (never seen
    /// by the training bank).  Returns `None` for problems without a
    /// native reference (the antiderivative is defined only up to a
    /// constant, so it has no pointwise truth).
    pub fn validate(&self, n_heldout: usize) -> Result<Option<NativeValidation>> {
        ensure!(n_heldout >= 1, "validation wants at least one function");
        let kind = self.config.problem;
        let q = self.config.q;
        // interior evaluation grid (strictly inside the domain)
        let g = 9usize;
        let mut pts = Vec::with_capacity(g * g);
        for i in 1..=g {
            for j in 1..=g {
                pts.push((i as f64 / (g + 1) as f64, j as f64 / (g + 1) as f64));
            }
        }
        let mut rng = Pcg64::new(self.config.seed ^ 0x5eed_cafe, 77);
        let mut pdata: Vec<f64> = Vec::with_capacity(n_heldout * q);
        let mut tdata: Vec<f64> = Vec::with_capacity(n_heldout * pts.len());
        match kind {
            ProblemKind::ReactionDiffusion => {
                let solver = ReactionDiffusionSolver::default();
                let prior = kind.function_prior().expect("rd has a GP prior");
                let sampler = GpSampler1d::new(prior, solver.nx);
                let bank = FunctionBank::generate(&sampler, n_heldout, &mut rng)?;
                for fi in 0..n_heldout {
                    pdata.extend(bank.sensors(fi, q));
                    tdata.extend(solver.solve_at(bank.values(fi), &pts));
                }
            }
            ProblemKind::Burgers => {
                let solver = BurgersSolver { nx: 128, ..Default::default() };
                let prior = kind.function_prior().expect("burgers has a GP prior");
                let sampler = GpSampler1d::new(prior, solver.nx);
                let bank = FunctionBank::generate(&sampler, n_heldout, &mut rng)?;
                // the solver grid is periodic: x_i = i / nx, no endpoint
                let xs: Vec<f64> =
                    (0..solver.nx).map(|i| i as f64 / solver.nx as f64).collect();
                for fi in 0..n_heldout {
                    pdata.extend(bank.sensors(fi, q));
                    let u0 = bank.eval_many(fi, &xs);
                    tdata.extend(solver.solve_at(&u0, &pts));
                }
            }
            ProblemKind::Kirchhoff => {
                let r = (q as f64).sqrt().round() as usize;
                ensure!(r * r == q, "kirchhoff sensors must be a square mode count");
                let rigidity = kind.constant("D_flex").expect("paper constant D_flex");
                let solver = KirchhoffSolver { rigidity, r_modes: r, s_modes: r };
                for _ in 0..n_heldout {
                    let c = rng.normals(q);
                    tdata.extend(solver.solve_at(&c, &pts));
                    pdata.extend(c);
                }
            }
            _ => return Ok(None),
        }
        let truth = Tensor::new(&[n_heldout, pts.len()], tdata);

        // predicted field from the trained weights, through the same
        // inference-only program the serving path runs (weights resident
        // as executor state, queries as the only per-run inputs)
        let dims = NetDims {
            q,
            hidden: self.config.hidden,
            k: self.config.k,
            coord_dim: self.coord_dim,
        };
        let fg = build_forward(n_heldout, dims, pts.len());
        let prog = Program::compile_inference(&fg.graph, &[fg.u], &fg.weight_ids);
        let mut exec = Executor::new().with_simd(SimdMode::Off);
        exec.bind_states(&prog, self.weights().to_vec());
        let columns: Vec<Tensor> = (0..fg.coords.len())
            .map(|c| {
                let col: Vec<f64> =
                    pts.iter().map(|pt| if c == 0 { pt.0 } else { pt.1 }).collect();
                Tensor::new(&[pts.len(), 1], col)
            })
            .collect();
        let mut shared: HashMap<NodeId, &Tensor> = HashMap::new();
        for (&node, col) in fg.coords.iter().zip(&columns) {
            shared.insert(node, col);
        }
        let sensor_rows: Vec<&[f64]> = pdata.chunks_exact(q).collect();
        let rows = exec.run_inference(&prog, fg.p, &sensor_rows, &shared);
        let flat: Vec<f64> = rows.into_iter().flatten().collect();
        let pred = Tensor::new(&[n_heldout, pts.len()], flat);
        Ok(Some(NativeValidation {
            rel_l2: pred.rel_l2_error(&truth),
            n_functions: n_heldout,
            n_points: pts.len(),
        }))
    }
}

/// The stepping half of a [`NativeTrainer`] ([`NativeTrainer::split`]):
/// either the single-program engine's per-step view or the whole replica
/// set, borrowed away from the batcher so the pipelined run can lend the
/// batcher to a producer thread while this stays on the training thread.
enum StepRef<'a> {
    Single(StepEngine<'a>),
    Replicated(&'a mut ReplicaSet),
}

impl StepRef<'_> {
    /// One optimizer step on one batch (see [`NativeTrainer::step`]).
    fn step(&mut self, batch: &PdeBatch) -> Result<(f64, f64, f64)> {
        match self {
            StepRef::Single(e) => e.step(batch),
            StepRef::Replicated(r) => r.step(batch),
        }
    }

    /// Snapshot (weights, Adam moments, optimizer step count) for a
    /// checkpoint, without giving up the split borrow (the pipelined run
    /// holds the batcher on another thread while saving).
    fn export_states(&self) -> (Vec<Tensor>, Vec<(Tensor, Tensor)>, u64) {
        match self {
            StepRef::Single(e) => e.export_states(),
            StepRef::Replicated(r) => r.export_states(),
        }
    }
}

/// Step once, transparently retrying after an *injected* worker panic.
///
/// A panic unwinds out of a step before the optimizer clock ticks or any
/// weight update commits, so the engine is exactly as it was before the
/// attempt and re-running the same batch is bit-exact.  Exactly one retry
/// is granted per injected fault ([`FaultCell::begin_recovery`]); real
/// panics and non-injected errors propagate untouched.
fn step_with_retry(
    engine: &mut StepRef<'_>,
    batch: &PdeBatch,
    fault: Option<&FaultCell>,
) -> Result<(f64, f64, f64)> {
    match engine.step(batch) {
        Err(e) if is_injected_panic(fault, &e) => engine.step(batch),
        r => r,
    }
}

/// True iff `e` is the worker panic we injected ourselves and its one
/// recovery attempt has not been spent yet.
fn is_injected_panic(fault: Option<&FaultCell>, e: &anyhow::Error) -> bool {
    let Some(cell) = fault else { return false };
    e.downcast_ref::<TrainError>()
        .is_some_and(|te| matches!(te, TrainError::WorkerPanic { .. }))
        && cell.begin_recovery(FaultKind::Panic)
}

/// The single-program stepping view: everything an `m == 1` step needs
/// except the batcher.
struct StepEngine<'a> {
    program: &'a Program,
    exec: &'a mut Executor,
    weights: &'a mut Vec<Tensor>,
    moments: &'a mut Vec<(Tensor, Tensor)>,
    host_t: &'a mut u64,
    n_weights: usize,
    resident: bool,
    lr: f64,
    optimizer: Optimizer,
    feeds: &'a [(String, NodeId)],
    extra_inputs: &'a [(NodeId, Tensor)],
    feed_plan: &'a [FeedSrc],
    feed_scratch: &'a mut Vec<*const Tensor>,
    fault: Option<Arc<FaultCell>>,
}

impl StepEngine<'_> {
    /// One optimizer step on one batch (see [`NativeTrainer::step`]).
    fn step(&mut self, batch: &PdeBatch) -> Result<(f64, f64, f64)> {
        ensure!(
            batch.feeds.len() == self.feeds.len(),
            "batch has {} feeds, the step program wants {}",
            batch.feeds.len(),
            self.feeds.len()
        );
        // resolve the precomputed feed plan into program-input order -- no
        // HashMap, no clones, just one reference per input, written into a
        // buffer whose capacity persists across steps
        let scratch = &mut *self.feed_scratch;
        scratch.clear();
        for src in self.feed_plan {
            let t: &Tensor = match *src {
                FeedSrc::Weight(i) => &self.weights[i],
                FeedSrc::Sensor => &batch.p,
                FeedSrc::Feed(i) => {
                    // batches arrive in registration order: positional fast
                    // path, name search only if a producer reordered them
                    let name = &self.feeds[i].0;
                    match batch.feeds.get(i) {
                        Some((n, t)) if n == name => t,
                        _ => batch
                            .feeds
                            .iter()
                            .find(|(n, _)| n == name)
                            .map(|(_, t)| t)
                            .ok_or_else(|| anyhow!("batch is missing feed {name:?}"))?,
                    }
                }
                FeedSrc::Extra(i) => &self.extra_inputs[i].1,
            };
            scratch.push(t as *const Tensor);
        }
        // 1-based step this call executes: the resident optimizer clock
        // (pre-increment) or the host timestep
        let step_no =
            if self.resident { self.exec.opt_steps() + 1 } else { *self.host_t + 1 };
        let (loss, loss_pde, loss_bc, mut grads) = {
            let scratch_ro: &Vec<*const Tensor> = scratch;
            let exec = &mut *self.exec;
            let program = self.program;
            let resident = self.resident;
            let fault = self.fault.clone();
            // catch a panicking kernel worker (or the injected fault):
            // the engine's state is untouched -- resident updates commit
            // only at the very end of a successful execute -- so the
            // caller may simply retry the step
            let outcome = catch_unwind(AssertUnwindSafe(move || {
                if let Some(cell) = &fault {
                    if cell.should_fire(FaultKind::Panic, step_no) {
                        panic!("zcs injected fault: step panic at step {step_no}");
                    }
                }
                // SAFETY: `&Tensor` and `*const Tensor` have identical
                // layout; every pointee (host weights, batch tensors,
                // extras) outlives this block and none is mutated while
                // borrowed -- the executor's resident state is disjoint
                // from the feeds
                let ins: &[&Tensor] = unsafe {
                    std::slice::from_raw_parts(
                        scratch_ro.as_ptr() as *const &Tensor,
                        scratch_ro.len(),
                    )
                };
                if resident {
                    let mut out = [0.0f64; 3];
                    exec.run_scalars(program, ins, &mut out);
                    (out[0], out[1], out[2], Vec::new())
                } else {
                    let mut outs = exec.run_inputs(program, ins);
                    let grads = outs.split_off(3);
                    (outs[0].data()[0], outs[1].data()[0], outs[2].data()[0], grads)
                }
            }));
            match outcome {
                Ok(v) => v,
                Err(payload) => {
                    self.feed_scratch.clear();
                    return Err(TrainError::WorkerPanic {
                        step: step_no,
                        what: panic_text(payload),
                    }
                    .into());
                }
            }
        };
        self.feed_scratch.clear();
        if let Some(trip) = self.exec.take_trip() {
            // the dynamic sanitizer fired: a non-finite output surfaces as
            // the same NonFinite variant the loss guard raises (so NaN
            // rollback keeps working) but with instruction-level
            // provenance; a race is an executor bug, never physics
            return Err(match trip {
                crate::autodiff::SanitizeTrip::NonFinite { .. } => TrainError::NonFinite {
                    step: step_no,
                    output: trip.to_string(),
                    value: f64::NAN,
                },
                crate::autodiff::SanitizeTrip::Race { .. } => {
                    TrainError::Sanitizer { step: step_no, what: trip.to_string() }
                }
            }
            .into());
        }
        for (name, v) in
            ["loss", "loss_pde", "loss_bc"].into_iter().zip([loss, loss_pde, loss_bc])
        {
            if !v.is_finite() {
                return Err(TrainError::NonFinite {
                    step: step_no,
                    output: name.to_string(),
                    value: v,
                }
                .into());
            }
        }
        if !self.resident {
            if let Some(cell) = &self.fault {
                // fallback NaN injection: poison the first weight
                // gradient before the guard, mirroring the resident
                // executor's in-update injection
                if cell.should_fire(FaultKind::NanGrad, step_no) {
                    if let Some(g) = grads.first_mut() {
                        g.data_mut().fill(f64::NAN);
                    }
                }
            }
            // non-finite gradient guard: refuse to commit a poisoned
            // update, leaving the host weights exactly as they were
            for (i, gw) in grads.iter().take(self.n_weights).enumerate() {
                if let Some(&bad) = gw.data().iter().find(|v| !v.is_finite()) {
                    return Err(TrainError::NonFinite {
                        step: step_no,
                        output: format!("grad[{i}]"),
                        value: bad,
                    }
                    .into());
                }
            }
            // host-side update through the same kernels the resident
            // update instructions run -- no `gw.scale(lr)` temporary
            *self.host_t += 1;
            let lr = self.lr;
            match self.optimizer {
                Optimizer::Sgd => {
                    for (w, gw) in self.weights.iter_mut().zip(&grads) {
                        crate::tensor::kernels::sgd_update(w, gw, lr);
                    }
                }
                Optimizer::Adam => {
                    for ((w, (m, v)), gw) in
                        self.weights.iter_mut().zip(self.moments.iter_mut()).zip(&grads)
                    {
                        crate::tensor::kernels::adam_update(
                            w,
                            m,
                            v,
                            gw,
                            lr,
                            Optimizer::BETA1,
                            Optimizer::BETA2,
                            Optimizer::EPS,
                            *self.host_t,
                        );
                    }
                }
            }
        }
        Ok((loss, loss_pde, loss_bc))
    }

    /// Snapshot (weights, Adam moments, optimizer step count) for a
    /// checkpoint; mirrors [`SingleEngine::export_states`] on the
    /// borrowed stepping view.
    fn export_states(&self) -> (Vec<Tensor>, Vec<(Tensor, Tensor)>, u64) {
        if self.resident {
            let states = self.exec.states();
            let weights: Vec<Tensor> = states[..self.n_weights].to_vec();
            let moments = if states.len() > self.n_weights {
                (0..self.n_weights)
                    .map(|i| {
                        (
                            states[self.n_weights + 2 * i].clone(),
                            states[self.n_weights + 2 * i + 1].clone(),
                        )
                    })
                    .collect()
            } else {
                Vec::new()
            };
            (weights, moments, self.exec.opt_steps())
        } else {
            (self.weights.clone(), self.moments.clone(), *self.host_t)
        }
    }
}

/// Rendezvous double-buffer between the batch producer thread and the
/// training loop.  Two [`PdeBatch`]es circulate -- one being filled, one
/// being stepped -- so the steady state allocates nothing, the producer
/// runs at most one draw ahead, and the batch sequence is exactly the
/// synchronous one (one batcher, drawn in order).
struct BatchPipe {
    state: Mutex<PipeState>,
    cv: Condvar,
}

/// Closes a [`BatchPipe`] when dropped (scope exit or unwind), so neither
/// side of the pipeline can block forever on a dead peer.
struct PipeCloser<'p>(&'p BatchPipe);

impl Drop for PipeCloser<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

struct PipeState {
    /// the next filled batch, in draw order, paired with the batcher's
    /// post-draw rng snapshot (what a checkpoint taken after stepping
    /// this batch must record to draw the next one on resume)
    full: Option<(PdeBatch, Pcg64Snapshot)>,
    /// a consumed batch handed back for refilling (seeded with the spare
    /// buffer so the producer starts one draw ahead)
    empty: Option<PdeBatch>,
    /// either side has hung up; all waits return immediately
    closed: bool,
}

impl BatchPipe {
    fn new() -> Self {
        Self {
            state: Mutex::new(PipeState {
                full: None,
                empty: Some(PdeBatch::empty()),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Producer: hand over a filled batch (plus the post-draw rng
    /// snapshot) and receive a buffer to refill; `None` once the consumer
    /// has closed the pipe.
    fn exchange(&self, filled: PdeBatch, snap: Pcg64Snapshot) -> Option<PdeBatch> {
        let mut st = self.state.lock().unwrap();
        while st.full.is_some() && !st.closed {
            st = self.cv.wait(st).unwrap();
        }
        if st.closed {
            return None;
        }
        st.full = Some((filled, snap));
        self.cv.notify_all();
        while st.empty.is_none() && !st.closed {
            st = self.cv.wait(st).unwrap();
        }
        if st.closed {
            return None;
        }
        st.empty.take()
    }

    /// Consumer: the next batch in draw order; `None` if the producer
    /// hung up before delivering one.
    fn take(&self) -> Option<(PdeBatch, Pcg64Snapshot)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(b) = st.full.take() {
                self.cv.notify_all();
                return Some(b);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Consumer: return a stepped batch for refilling.
    fn recycle(&self, batch: PdeBatch) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.empty.is_none(), "more than two batches in flight");
        st.empty = Some(batch);
        self.cv.notify_all();
    }

    /// Hang up (either side): every pending and future wait returns.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(strategy: Strategy) -> NativeRunConfig {
        NativeRunConfig {
            problem: ProblemKind::Antiderivative,
            strategy,
            m: 2,
            n: 6,
            n_bc: 4,
            q: 5,
            hidden: 8,
            k: 4,
            steps: 40,
            lr: 5e-3,
            seed: 7,
            bank_size: 8,
            bank_grid: 32,
            log_every: 1,
            threads: 1,
            ..NativeRunConfig::default()
        }
    }

    #[test]
    fn native_training_reduces_loss() {
        let mut trainer = NativeTrainer::new(tiny(Strategy::Zcs)).unwrap();
        let report = trainer.run().unwrap();
        assert_eq!(report.steps, 40);
        assert!(report.final_loss.is_finite());
        // robust to batch noise: average the first vs the last 5 points
        let losses: Vec<f64> = report.curve.iter().map(|p| p.loss).collect();
        let head: f64 = losses[..5].iter().sum::<f64>() / 5.0;
        let tail: f64 = losses[losses.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(tail < head, "loss did not trend down: {head:.4} -> {tail:.4}");
        // the step program was compiled, not interpreted
        assert!(report.program.stats.instructions > 0);
        assert!(report.program.stats.instructions < report.program.stats.graph_nodes);
        // the antiderivative has no boundary term
        assert!(report.curve.iter().all(|p| p.loss_bc == 0.0));
    }

    #[test]
    fn strategies_share_the_loss_trajectory() {
        // same seed => same batches => identical math, so the three
        // strategies must produce (numerically) the same loss sequence
        for problem in [ProblemKind::Antiderivative, ProblemKind::ReactionDiffusion] {
            let losses: Vec<Vec<f64>> = Strategy::ALL
                .iter()
                .map(|&s| {
                    let mut cfg = tiny(s);
                    cfg.problem = problem;
                    cfg.steps = 3;
                    let mut tr = NativeTrainer::new(cfg).unwrap();
                    let rep = tr.run().unwrap();
                    rep.curve.iter().map(|p| p.loss).collect()
                })
                .collect();
            for other in &losses[1..] {
                for (a, b) in losses[0].iter().zip(other) {
                    assert!(
                        (a - b).abs() <= 1e-8 * (1.0 + a.abs()),
                        "{problem:?}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        // d loss / d wb2[0,0] by central FD on a frozen batch; m == 1
        // runs the single-program engine, whose feed-based fallback
        // exposes the gradient outputs this test reads
        let mut cfg = tiny(Strategy::Zcs);
        cfg.m = 1;
        cfg.resident = false;
        let mut trainer = NativeTrainer::new(cfg).unwrap();
        let batch = trainer.batcher.next_batch();
        let Engine::Single(engine) = &mut trainer.engine else {
            panic!("m == 1 must run the single-program engine");
        };

        let mut inputs: HashMap<NodeId, Tensor> = HashMap::new();
        for (id, w) in engine.weight_ids.iter().zip(&engine.weights) {
            inputs.insert(*id, w.clone());
        }
        inputs.insert(engine.p_id, batch.p.clone());
        for (name, node) in &engine.feeds {
            let t = batch.feeds.iter().find(|(n, _)| n == name).unwrap().1.clone();
            inputs.insert(*node, t);
        }
        for (id, t) in &engine.extra_inputs {
            inputs.insert(*id, t.clone());
        }
        let outs = engine.exec.run(&engine.program, &inputs);
        let analytic = outs[4].data()[0]; // d loss / d wb2, first entry

        let h = 1e-6;
        let mut loss_at = |delta: f64| -> f64 {
            let mut shifted = inputs.clone();
            let mut w = engine.weights[1].clone();
            w.data_mut()[0] += delta;
            shifted.insert(engine.weight_ids[1], w);
            engine.exec.run(&engine.program, &shifted)[0].data()[0]
        };
        let fd = (loss_at(h) - loss_at(-h)) / (2.0 * h);
        assert!(
            (analytic - fd).abs() < 1e-5 * (1.0 + analytic.abs()),
            "{analytic} vs {fd}"
        );
    }

    #[test]
    fn threaded_training_is_bit_identical_to_serial() {
        let losses_at = |threads: usize| -> Vec<f64> {
            let mut cfg = tiny(Strategy::Zcs);
            cfg.steps = 5;
            cfg.threads = threads;
            let mut trainer = NativeTrainer::new(cfg).unwrap();
            assert_eq!(trainer.threads(), threads);
            let report = trainer.run().unwrap();
            report.curve.iter().map(|p| p.loss).collect()
        };
        let serial = losses_at(1);
        for threads in [2usize, 4] {
            assert_eq!(serial, losses_at(threads), "{threads} threads drifted");
        }
    }

    #[test]
    fn per_problem_default_lr_is_sane() {
        assert_eq!(NativeRunConfig::default_lr(ProblemKind::Burgers), 1e-2);
        assert!(NativeRunConfig::default_lr(ProblemKind::Kirchhoff) < 1e-2);
    }

    #[test]
    fn optimizer_parses_case_insensitively_and_lists_choices() {
        assert_eq!(Optimizer::parse("SGD").unwrap(), Optimizer::Sgd);
        assert_eq!(Optimizer::parse("Adam").unwrap(), Optimizer::Adam);
        let err = Optimizer::parse("lbfgs").unwrap_err();
        assert!(err.contains("sgd") && err.contains("adam"), "{err}");
    }

    #[test]
    fn resident_training_reduces_loss_under_adam() {
        let mut cfg = tiny(Strategy::Zcs);
        cfg.optimizer = Optimizer::Adam;
        cfg.lr = 1e-2;
        let mut trainer = NativeTrainer::new(cfg).unwrap();
        assert!(trainer.resident());
        assert!(trainer.resident_state_bytes() > 0);
        let report = trainer.run().unwrap();
        assert_eq!(report.optimizer, Optimizer::Adam);
        assert_eq!(report.resident_state_bytes, trainer.resident_state_bytes());
        // Adam carries 3x the weight bytes (w + m + v)
        let weight_bytes: u64 =
            trainer.weights().iter().map(|w| w.len() as u64 * 8).sum();
        assert_eq!(report.resident_state_bytes, 3 * weight_bytes);
        let losses: Vec<f64> = report.curve.iter().map(|p| p.loss).collect();
        let head: f64 = losses[..5].iter().sum::<f64>() / 5.0;
        let tail: f64 = losses[losses.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(tail < head, "adam loss did not trend down: {head:.4} -> {tail:.4}");
        // the optimizer runs inside the program
        assert_eq!(report.program.opcode_histogram["adam-update"], 4);
        assert!(report.steps_per_sec() > 0.0);
    }

    #[test]
    fn resident_and_feed_based_sgd_share_one_trajectory() {
        // the exhaustive problem x strategy x size sweep lives in
        // rust/tests/resident_step.rs; this is the in-module smoke check
        let mut resident_cfg = tiny(Strategy::Zcs);
        resident_cfg.steps = 6;
        let mut fallback_cfg = resident_cfg.clone();
        fallback_cfg.resident = false;
        let mut a = NativeTrainer::new(resident_cfg).unwrap();
        let mut b = NativeTrainer::new(fallback_cfg).unwrap();
        let ra = a.run().unwrap();
        let rb = b.run().unwrap();
        assert!(ra.resident_state_bytes > 0);
        assert_eq!(rb.resident_state_bytes, 0);
        for (pa, pb) in ra.curve.iter().zip(&rb.curve) {
            assert_eq!(pa.loss, pb.loss, "step {}", pa.step);
            assert_eq!(pa.loss_pde, pb.loss_pde);
            assert_eq!(pa.loss_bc, pb.loss_bc);
        }
        assert_eq!(a.weights(), b.weights());
    }
}
