//! Native training loop: compiled [`Program`]s executed inside the train
//! loop, no artifacts or PJRT anywhere.
//!
//! The workload is the canonical operator-learning benchmark: learn the
//! *antiderivative* operator.  A miniature DeepONet `u_ij = branch(p_i) .
//! trunk(x_j)` is trained so that its coordinate derivative matches the
//! input function, `du_i/dx (x_j) = f_i(x_j)` -- a physics-informed loss
//! whose residual is itself a derivative, so the loss gradient w.r.t. the
//! weights differentiates *through* the chosen AD strategy (eq. 4 FuncLoop,
//! eq. 5 DataVect, or the eq. 10 ZCS z-chain), exactly like the paper's
//! PDE losses.
//!
//! The entire step -- forward, strategy derivative, residual, weight
//! gradients -- is built as one [`Graph`], lowered **once** by
//! [`Program::compile`], and then executed every step by a persistent
//! [`Executor`] (compile-once / run-many).  [`NativeReport`] carries the
//! same staged timings as the PJRT [`super::TrainReport`], plus the
//! compiler's [`ProgramReport`], so `zcs ntrain` and the benches can put
//! interpreted vs compiled and strategy vs strategy numbers side by side.

use crate::autodiff::zcs_demo::Strategy;
use crate::autodiff::{Executor, Graph, NodeId, Program};
use crate::coordinator::batch::{NativeBatch, NativeBatcher};
use crate::hlostats::{analyze_program, ProgramReport};
use crate::rng::Pcg64;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Configuration of a native training run.
#[derive(Clone, Debug)]
pub struct NativeRunConfig {
    pub strategy: Strategy,
    /// functions per batch (the paper's M)
    pub m: usize,
    /// collocation points per batch (the paper's N)
    pub n: usize,
    /// branch sensors (the paper's Q)
    pub q: usize,
    /// hidden width of both MLPs
    pub hidden: usize,
    /// latent combine dimension (the DeepONet K)
    pub k: usize,
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub bank_size: usize,
    pub bank_grid: usize,
    pub log_every: usize,
}

impl Default for NativeRunConfig {
    fn default() -> Self {
        Self {
            strategy: Strategy::Zcs,
            m: 4,
            n: 16,
            q: 8,
            hidden: 16,
            k: 8,
            steps: 200,
            lr: 1e-2,
            seed: 20230923,
            bank_size: 64,
            bank_grid: 128,
            log_every: 20,
        }
    }
}

/// Outcome of a native run.
#[derive(Clone, Debug)]
pub struct NativeReport {
    pub curve: Vec<(usize, f64)>,
    pub final_loss: f64,
    pub steps: usize,
    /// batch generation time (the paper's "Inputs" stage)
    pub input_time: Duration,
    /// time inside compiled-program execution
    pub step_time: Duration,
    /// graph build + compile time (paid once)
    pub compile_time: Duration,
    /// compiler statistics of the step program
    pub program: ProgramReport,
}

impl NativeReport {
    /// Paper-style "time per 1000 batches" in seconds.
    pub fn sec_per_1000(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.step_time.as_secs_f64() / self.steps as f64 * 1000.0
    }
}

/// The native training orchestrator: one compiled step program + a
/// persistent executor + host-side SGD.
pub struct NativeTrainer {
    pub config: NativeRunConfig,
    program: Program,
    exec: Executor,
    batcher: NativeBatcher,
    /// wb (q,h), wb2 (h,k), wt (1,h), wt2 (h,k)
    weights: Vec<Tensor>,
    weight_ids: Vec<NodeId>,
    p_id: NodeId,
    x_id: NodeId,
    target_id: NodeId,
    extra_inputs: Vec<(NodeId, Tensor)>,
    compile_time: Duration,
}

impl NativeTrainer {
    pub fn new(config: NativeRunConfig) -> Result<Self> {
        ensure!(config.m >= 1 && config.n >= 1 && config.q >= 1, "empty problem");
        let t0 = Instant::now();
        let build = build_step_graph(&config);
        let program = Program::compile(&build.graph, &build.outputs);
        let compile_time = t0.elapsed();

        let mut init_rng = Pcg64::new(config.seed, 2);
        let (q, h, k) = (config.q, config.hidden, config.k);
        let mk = |r: usize, c: usize, rng: &mut Pcg64| {
            Tensor::new(&[r, c], rng.normals(r * c)).scale(1.0 / (r as f64).sqrt())
        };
        let weights = vec![
            mk(q, h, &mut init_rng),
            mk(h, k, &mut init_rng),
            mk(1, h, &mut init_rng),
            mk(h, k, &mut init_rng),
        ];
        let mut batch_rng = Pcg64::new(config.seed, 1);
        let batcher = NativeBatcher::new(
            config.m,
            config.n,
            config.q,
            config.bank_size,
            config.bank_grid,
            &mut batch_rng,
        )?;
        Ok(Self {
            config,
            program,
            exec: Executor::new(),
            batcher,
            weights,
            weight_ids: build.weight_ids,
            p_id: build.p,
            x_id: build.x,
            target_id: build.target,
            extra_inputs: build.extra_inputs,
            compile_time,
        })
    }

    /// Compiler statistics of the step program.
    pub fn program_report(&self) -> ProgramReport {
        analyze_program(&self.program)
    }

    /// Current weights (wb, wb2, wt, wt2).
    pub fn weights(&self) -> &[Tensor] {
        &self.weights
    }

    /// One SGD step on one batch; returns the loss.
    pub fn step(&mut self, batch: &NativeBatch) -> Result<f64> {
        // only DataVect needs an owned (re-laid-out) target; everything
        // else is fed by reference -- no tensor clones in the hot loop
        let target_owned = match self.config.strategy {
            Strategy::DataVect => Some(reshape_target(&batch.f_at_x, Strategy::DataVect)),
            _ => None,
        };
        let target: &Tensor = target_owned.as_ref().unwrap_or(&batch.f_at_x);
        let mut inputs: HashMap<NodeId, &Tensor> = HashMap::new();
        for (id, w) in self.weight_ids.iter().zip(&self.weights) {
            inputs.insert(*id, w);
        }
        inputs.insert(self.p_id, &batch.p);
        inputs.insert(self.x_id, &batch.x);
        inputs.insert(self.target_id, target);
        for (id, t) in &self.extra_inputs {
            inputs.insert(*id, t);
        }
        let outs = self.exec.run_ref(&self.program, &inputs);
        let loss = outs[0].data()[0];
        if !loss.is_finite() {
            bail!("native loss diverged: {loss}");
        }
        for (w, gw) in self.weights.iter_mut().zip(outs.into_iter().skip(1)) {
            *w = &*w - &gw.scale(self.config.lr);
        }
        Ok(loss)
    }

    /// Run the configured number of steps.
    pub fn run(&mut self) -> Result<NativeReport> {
        let mut curve = Vec::new();
        let mut input_time = Duration::ZERO;
        let mut step_time = Duration::ZERO;
        let mut last = f64::NAN;
        for it in 0..self.config.steps {
            let t0 = Instant::now();
            let batch = self.batcher.next_batch();
            input_time += t0.elapsed();
            let t1 = Instant::now();
            last = self.step(&batch)?;
            step_time += t1.elapsed();
            if (it + 1) % self.config.log_every.max(1) == 0 || it + 1 == self.config.steps {
                curve.push((it + 1, last));
            }
        }
        Ok(NativeReport {
            curve,
            final_loss: last,
            steps: self.config.steps,
            input_time,
            step_time,
            compile_time: self.compile_time,
            program: self.program_report(),
        })
    }
}

/// The (m, n) target in the layout the strategy's residual expects.
fn reshape_target(f_at_x: &Tensor, strategy: Strategy) -> Tensor {
    match strategy {
        // DataVect residuals are tiled rows: (m*n, 1), same row-major data
        Strategy::DataVect => {
            let (m, n) = (f_at_x.shape()[0], f_at_x.shape()[1]);
            f_at_x.clone().reshape(&[m * n, 1])
        }
        _ => f_at_x.clone(),
    }
}

/// Everything the trainer needs to feed the compiled step program.
struct StepGraph {
    graph: Graph,
    /// [loss, d loss/d wb, d loss/d wb2, d loss/d wt, d loss/d wt2]
    outputs: Vec<NodeId>,
    weight_ids: Vec<NodeId>,
    p: NodeId,
    x: NodeId,
    target: NodeId,
    extra_inputs: Vec<(NodeId, Tensor)>,
}

/// Build the full training-step graph: forward, strategy derivative,
/// residual vs target, weight gradients.
fn build_step_graph(config: &NativeRunConfig) -> StepGraph {
    let (m, n, q, h, k) = (config.m, config.n, config.q, config.hidden, config.k);
    let mut g = Graph::new();
    let wb = g.input(&[q, h]);
    let wb2 = g.input(&[h, k]);
    let wt = g.input(&[1, h]);
    let wt2 = g.input(&[h, k]);
    let p = g.input(&[m, q]);
    let x = g.input(&[n, 1]);

    let branch = |g: &mut Graph, pin: NodeId| {
        let hb = g.matmul(pin, wb);
        let ab = g.tanh(hb);
        g.matmul(ab, wb2)
    };
    let trunk = |g: &mut Graph, xin: NodeId| {
        let ht = g.matmul(xin, wt);
        let at = g.tanh(ht);
        g.matmul(at, wt2)
    };
    let norm = 1.0 / (m * n) as f64;

    let mut extra_inputs: Vec<(NodeId, Tensor)> = Vec::new();
    let (target, loss) = match config.strategy {
        Strategy::Zcs => {
            let target = g.input(&[m, n]);
            // eq. (6) shift + eq. (9) dummy summation + eq. (10) z-chain
            let z = g.input(&[]);
            let zb = g.broadcast(z, &[n, 1]);
            let xz = g.add(x, zb);
            let b = branch(&mut g, p);
            let t = trunk(&mut g, xz);
            let u = g.matmul_nt(b, t); // (m, n)
            let a = g.input(&[m, n]);
            let au = g.mul(a, u);
            let omega = g.sum_all(au);
            let dz = g.grad(omega, &[z])[0];
            let du = g.grad(dz, &[a])[0]; // (m, n) = du_ij/dx_j
            let r = g.sub(du, target);
            let r2 = g.mul(r, r);
            let sum = g.sum_all(r2);
            let loss = g.scale(sum, norm);
            extra_inputs.push((z, Tensor::new(&[], vec![0.0])));
            extra_inputs.push((a, Tensor::full(&[m, n], 1.0)));
            (target, loss)
        }
        Strategy::FuncLoop => {
            let target = g.input(&[m, n]);
            let b = branch(&mut g, p);
            let t = trunk(&mut g, x);
            let u = g.matmul_nt(b, t); // (m, n)
            // eq. (4): one reverse pass per function
            let mut acc: Option<NodeId> = None;
            for i in 0..m {
                let mut e = Tensor::zeros(&[1, m]);
                e.data_mut()[i] = 1.0;
                let ei = g.constant(e);
                let row = g.matmul(ei, u); // (1, n)
                let root = g.sum_all(row);
                let dx = g.grad(root, &[x])[0]; // (n, 1)
                let dxt = g.transpose_of(dx); // (1, n)
                let trow = g.matmul(ei, target); // (1, n)
                let r = g.sub(dxt, trow);
                let r2 = g.mul(r, r);
                let li = g.sum_all(r2);
                acc = Some(match acc {
                    Some(prev) => g.add(prev, li),
                    None => li,
                });
            }
            let loss = g.scale(acc.expect("m >= 1"), norm);
            (target, loss)
        }
        Strategy::DataVect => {
            // eq. (5): tiled pointwise rows; the target arrives pre-tiled
            let target = g.input(&[m * n, 1]);
            let mut rp = Tensor::zeros(&[m * n, m]);
            let mut rx = Tensor::zeros(&[m * n, n]);
            for i in 0..m {
                for j in 0..n {
                    rp.data_mut()[(i * n + j) * m + i] = 1.0;
                    rx.data_mut()[(i * n + j) * n + j] = 1.0;
                }
            }
            let rp = g.constant(rp);
            let rx = g.constant(rx);
            let ph = g.matmul(rp, p); // (mn, q)
            let xh = g.matmul(rx, x); // (mn, 1)
            let b = branch(&mut g, ph); // (mn, k)
            let t = trunk(&mut g, xh); // (mn, k)
            let bt = g.mul(b, t);
            let ones = g.constant(Tensor::full(&[k, 1], 1.0));
            let u_rows = g.matmul(bt, ones); // (mn, 1)
            let root = g.sum_all(u_rows);
            let dxh = g.grad(root, &[xh])[0]; // (mn, 1)
            let r = g.sub(dxh, target);
            let r2 = g.mul(r, r);
            let sum = g.sum_all(r2);
            let loss = g.scale(sum, norm);
            (target, loss)
        }
    };

    let weight_ids = vec![wb, wb2, wt, wt2];
    let grads = g.grad(loss, &weight_ids);
    let mut outputs = vec![loss];
    outputs.extend(grads);
    StepGraph { graph: g, outputs, weight_ids, p, x, target, extra_inputs }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(strategy: Strategy) -> NativeRunConfig {
        NativeRunConfig {
            strategy,
            m: 2,
            n: 6,
            q: 5,
            hidden: 8,
            k: 4,
            steps: 40,
            lr: 5e-3,
            seed: 7,
            bank_size: 8,
            bank_grid: 32,
            log_every: 1,
        }
    }

    #[test]
    fn native_training_reduces_loss() {
        let mut trainer = NativeTrainer::new(tiny(Strategy::Zcs)).unwrap();
        let report = trainer.run().unwrap();
        assert_eq!(report.steps, 40);
        assert!(report.final_loss.is_finite());
        // robust to batch noise: average the first vs the last 5 points
        let losses: Vec<f64> = report.curve.iter().map(|&(_, l)| l).collect();
        let head: f64 = losses[..5].iter().sum::<f64>() / 5.0;
        let tail: f64 = losses[losses.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(tail < head, "loss did not trend down: {head:.4} -> {tail:.4}");
        // the step program was compiled, not interpreted
        assert!(report.program.stats.instructions > 0);
        assert!(report.program.stats.instructions < report.program.stats.graph_nodes);
    }

    #[test]
    fn strategies_share_the_loss_trajectory() {
        // same seed => same batches => identical math, so the three
        // strategies must produce (numerically) the same loss sequence
        let losses: Vec<Vec<f64>> = [Strategy::Zcs, Strategy::FuncLoop, Strategy::DataVect]
            .iter()
            .map(|&s| {
                let mut cfg = tiny(s);
                cfg.steps = 3;
                let mut tr = NativeTrainer::new(cfg).unwrap();
                let rep = tr.run().unwrap();
                rep.curve.iter().map(|&(_, l)| l).collect()
            })
            .collect();
        for other in &losses[1..] {
            for (a, b) in losses[0].iter().zip(other) {
                assert!((a - b).abs() <= 1e-8 * (1.0 + a.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        // d loss / d wb2[0,0] by central FD on a frozen batch
        let cfg = tiny(Strategy::Zcs);
        let mut trainer = NativeTrainer::new(cfg).unwrap();
        let batch = trainer.batcher.next_batch();

        // analytic gradient from the compiled program
        let target = reshape_target(&batch.f_at_x, trainer.config.strategy);
        let mut inputs: HashMap<NodeId, Tensor> = HashMap::new();
        for (id, w) in trainer.weight_ids.iter().zip(&trainer.weights) {
            inputs.insert(*id, w.clone());
        }
        inputs.insert(trainer.p_id, batch.p.clone());
        inputs.insert(trainer.x_id, batch.x.clone());
        inputs.insert(trainer.target_id, target);
        for (id, t) in &trainer.extra_inputs {
            inputs.insert(*id, t.clone());
        }
        let outs = trainer.exec.run(&trainer.program, &inputs);
        let analytic = outs[2].data()[0]; // d loss / d wb2, first entry

        let h = 1e-6;
        let mut loss_at = |delta: f64| -> f64 {
            let mut shifted = inputs.clone();
            let mut w = trainer.weights[1].clone();
            w.data_mut()[0] += delta;
            shifted.insert(trainer.weight_ids[1], w);
            trainer.exec.run(&trainer.program, &shifted)[0].data()[0]
        };
        let fd = (loss_at(h) - loss_at(-h)) / (2.0 * h);
        assert!(
            (analytic - fd).abs() < 1e-5 * (1.0 + analytic.abs()),
            "{analytic} vs {fd}"
        );
    }
}
