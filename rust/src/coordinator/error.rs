//! Typed training failures.
//!
//! The native trainer's error path used to be stringly `anyhow::bail!`;
//! crash-safe training needs callers (the CLI, tests, recovery code) to
//! distinguish *what* failed: a diverged loss can roll back to the last
//! checkpoint, a panicking worker can be retried or surfaced, a bad
//! checkpoint file must abort the resume.  [`TrainError`] is that
//! taxonomy; it flows through the existing `anyhow::Result` plumbing and
//! is recovered with `err.downcast_ref::<TrainError>()`.

use std::fmt;

/// What went wrong inside a training run.
#[derive(Clone, Debug, PartialEq)]
pub enum TrainError {
    /// A loss component (or a gradient, in the fallback path) came out
    /// non-finite: the offending output is named so the report points at
    /// the physics, not just "NaN somewhere".
    NonFinite {
        /// 1-based training step at which the value was observed
        step: u64,
        /// which output went bad (`loss`, `loss_pde`, `loss_bc`, `grad`)
        output: String,
        value: f64,
    },
    /// A worker or replica driver thread panicked mid-step.  The panic
    /// payload is carried as text; the step state is guaranteed
    /// unmodified (panics happen before the in-Program optimizer update
    /// commits), so the step can be retried.
    WorkerPanic {
        /// 1-based training step that was being executed
        step: u64,
        /// stringified panic payload
        what: String,
    },
    /// A checkpoint could not be loaded, validated, or applied.
    Checkpoint { reason: String },
    /// A replica (or the serve dispatcher) failed to make progress within
    /// the stall deadline (`ZCS_STALL_MS`): the watchdog converted what
    /// would have been a silent hang into this error, carrying the
    /// stalling party's state dump.
    Stalled {
        /// 1-based training step that was being executed (0 when the
        /// stall is outside a training step, e.g. in serving)
        step: u64,
        /// watchdog state dump (who stalled, parties arrived, deadline)
        what: String,
    },
    /// The dynamic sanitizer (`ZCS_SANITIZE=full`) tripped on something
    /// that is not a non-finite value -- e.g. an unordered slot access
    /// the schedule should have made impossible.  Always a bug in the
    /// compiler/executor, never in the physics; not retried.
    Sanitizer {
        /// 1-based training step at which the trip was observed
        step: u64,
        /// the trip report ([`crate::autodiff::SanitizeTrip`] rendering)
        what: String,
    },
}

impl fmt::Display for TrainError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrainError::NonFinite { step, output, value } => {
                write!(f, "non-finite {output} at step {step}: {value}")
            }
            TrainError::WorkerPanic { step, what } => {
                write!(f, "worker panicked at step {step}: {what}")
            }
            TrainError::Checkpoint { reason } => write!(f, "checkpoint error: {reason}"),
            TrainError::Stalled { step, what } => {
                write!(f, "stalled at step {step}: {what}")
            }
            TrainError::Sanitizer { step, what } => {
                write!(f, "sanitizer trip at step {step}: {what}")
            }
        }
    }
}

impl std::error::Error for TrainError {}

/// Stringify a panic payload (panics carry `&str` or `String` in
/// practice; anything else is reported opaquely).
pub(crate) fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_name_the_failure() {
        let e = TrainError::NonFinite { step: 7, output: "loss_pde".into(), value: f64::NAN };
        let s = e.to_string();
        assert!(s.contains("loss_pde") && s.contains("step 7"), "{s}");
        let e = TrainError::WorkerPanic { step: 3, what: "boom".into() };
        assert!(e.to_string().contains("boom"));
        let e = TrainError::Stalled { step: 5, what: "1 of 2 parties".into() };
        let s = e.to_string();
        assert!(s.contains("stalled") && s.contains("step 5") && s.contains("parties"), "{s}");
        let e = TrainError::Sanitizer { step: 9, what: "unordered write/write".into() };
        let s = e.to_string();
        assert!(s.contains("sanitizer") && s.contains("write/write"), "{s}");
    }

    #[test]
    fn downcasts_through_anyhow() {
        let err: anyhow::Error =
            TrainError::WorkerPanic { step: 2, what: "injected".into() }.into();
        let got = err.downcast_ref::<TrainError>().expect("typed error survives anyhow");
        assert!(matches!(got, TrainError::WorkerPanic { step: 2, .. }));
    }
}
