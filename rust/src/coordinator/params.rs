//! Parameter initialisation per the manifest layout.
//!
//! Same scheme as `python/compile/model.init_params` (Glorot-uniform
//! matrices, zero biases); the exact stream differs, which is fine -- the
//! paper itself averages over weight initialisations.

use crate::rng::Pcg64;
use crate::runtime::HostTensor;

/// Initialise the flat parameter tuple described by `layout`.
pub fn init_params(layout: &[(String, Vec<usize>)], rng: &mut Pcg64) -> Vec<HostTensor> {
    layout
        .iter()
        .map(|(name, shape)| {
            let count: usize = shape.iter().product();
            if shape.len() == 2 {
                let limit = (6.0 / (shape[0] + shape[1]) as f64).sqrt();
                let data: Vec<f32> =
                    (0..count).map(|_| rng.uniform_in(-limit, limit) as f32).collect();
                HostTensor::new(shape.clone(), data)
            } else {
                // biases (and the output bias) start at zero
                let _ = name;
                HostTensor::zeros(shape)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> Vec<(String, Vec<usize>)> {
        vec![
            ("w0".into(), vec![50, 64]),
            ("b0".into(), vec![64]),
            ("w1".into(), vec![64, 64]),
            ("b1".into(), vec![64]),
            ("bias".into(), vec![1]),
        ]
    }

    #[test]
    fn shapes_match_layout() {
        let mut rng = Pcg64::seeded(0);
        let ps = init_params(&layout(), &mut rng);
        assert_eq!(ps.len(), 5);
        assert_eq!(ps[0].dims, vec![50, 64]);
        assert_eq!(ps[4].dims, vec![1]);
    }

    #[test]
    fn glorot_bounds_hold() {
        let mut rng = Pcg64::seeded(1);
        let ps = init_params(&layout(), &mut rng);
        let limit = (6.0f64 / (50 + 64) as f64).sqrt() as f32;
        assert!(ps[0].data.iter().all(|v| v.abs() <= limit));
        assert!(ps[0].data.iter().any(|v| v.abs() > 0.5 * limit));
    }

    #[test]
    fn biases_are_zero() {
        let mut rng = Pcg64::seeded(2);
        let ps = init_params(&layout(), &mut rng);
        assert!(ps[1].data.iter().all(|&v| v == 0.0));
        assert!(ps[4].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = init_params(&layout(), &mut Pcg64::seeded(3));
        let b = init_params(&layout(), &mut Pcg64::seeded(3));
        assert_eq!(a[0].data, b[0].data);
        let c = init_params(&layout(), &mut Pcg64::seeded(4));
        assert_ne!(a[0].data, c[0].data);
    }
}
