//! Figure-3 reproduction: true vs predicted Stokes fields for the parabolic
//! lid `u1(x) = x (1 - x)`.
//!
//! Trains a ZCS DeepONet briefly, evaluates it on a 64 x 64 grid with the
//! parabolic lid in function slot 0, computes the reference solution with
//! the vorticity-streamfunction solver, and writes `pred.csv` / `true.csv`
//! (columns: x, y, u, v, p) plus a `summary.txt` with per-channel errors.

use crate::config::RunConfig;
use crate::coordinator::{validate::GRID_SIDE, Trainer};
use crate::runtime::{HostTensor, RunArg, Runtime};
use crate::sampler::tensor_grid_2d;
use crate::solvers::StokesSolver;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::io::Write;
use std::rc::Rc;

/// Train + dump. Returns per-channel relative L2 errors on the lid case.
pub fn dump_stokes_fields(config: RunConfig, out_dir: &str) -> Result<Vec<f64>> {
    if config.problem != "stokes" {
        bail!("fields dump is a Stokes (Fig. 3) feature");
    }
    std::fs::create_dir_all(out_dir)?;
    let runtime = Rc::new(Runtime::open(&config.artifact_dir)?);
    let mut trainer = Trainer::new(runtime.clone(), config.clone())?;
    let report = trainer.run()?;

    // forward artifact at the 64 x 64 grid
    let g = GRID_SIDE * GRID_SIDE;
    let exe = runtime.load(&format!("stokes__forward_G{g}"))?;
    let m = exe.meta.inputs[exe.meta.inputs.len() - 2].shape[0];
    let q = exe.meta.inputs[exe.meta.inputs.len() - 2].shape[1];

    // function slot 0: the paper's parabolic lid; other slots: bank samples
    let mut p = trainer.batcher().sensors_for(&(0..m).collect::<Vec<_>>());
    for k in 0..q {
        let x = k as f64 / (q - 1) as f64;
        p.data[k] = (x * (1.0 - x)) as f32;
    }
    let grid = tensor_grid_2d(GRID_SIDE, GRID_SIDE);
    let mut args: Vec<RunArg> =
        trainer.state.params.iter().cloned().map(RunArg::F32).collect();
    args.push(RunArg::F32(p));
    args.push(RunArg::F32(HostTensor::from_f64(vec![g, 2], grid.data())));
    let u = &exe.run(&args)?[0]; // (3, m, g)

    // reference solution
    let solver = StokesSolver::default();
    let xs = Tensor::linspace(0.0, 1.0, solver.n).into_data();
    let lid: Vec<f64> = xs.iter().map(|&x| x * (1.0 - x)).collect();
    let fields = solver.solve(&lid);

    let mut pred = std::fs::File::create(format!("{out_dir}/pred.csv"))?;
    let mut tru = std::fs::File::create(format!("{out_dir}/true.csv"))?;
    writeln!(pred, "x,y,u,v,p")?;
    writeln!(tru, "x,y,u,v,p")?;
    let mut num = [0.0f64; 3];
    let mut den = [0.0f64; 3];
    for r in 0..g {
        let (x, y) = (grid.at2(r, 0), grid.at2(r, 1));
        let pu = u.data[r] as f64; // channel 0, function 0
        let pv = u.data[g * m + r] as f64;
        let pp = u.data[2 * g * m + r] as f64;
        let (tu, tv, tp) = fields.at(x, y);
        writeln!(pred, "{x},{y},{pu},{pv},{pp}")?;
        writeln!(tru, "{x},{y},{tu},{tv},{tp}")?;
        for (c, (a, b)) in [(pu, tu), (pv, tv), (pp, tp)].into_iter().enumerate() {
            num[c] += (a - b) * (a - b);
            den[c] += b * b;
        }
    }
    let errors: Vec<f64> =
        (0..3).map(|c| (num[c] / den[c].max(1e-300)).sqrt()).collect();
    let mut summary = std::fs::File::create(format!("{out_dir}/summary.txt"))?;
    writeln!(summary, "final training loss: {:.6e}", report.final_loss)?;
    for (label, e) in ["u", "v", "p"].iter().zip(&errors) {
        writeln!(summary, "rel L2 error [{label}]: {:.2}%", e * 100.0)?;
    }
    Ok(errors)
}
