//! Flat-parameter checkpointing: a tiny self-describing binary format.
//!
//! Layout: magic `ZCSCKPT1`, tensor count (u32 LE), then per tensor:
//! rank (u32), dims (u32 each), f32 data (LE).  No external deps, stable
//! across platforms we care about.

use crate::runtime::HostTensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"ZCSCKPT1";

/// Save the flat parameter tuple.
pub fn save(path: impl AsRef<Path>, params: &[HostTensor]) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for t in params {
        f.write_all(&(t.dims.len() as u32).to_le_bytes())?;
        for &d in &t.dims {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        for &v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load a checkpoint.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<HostTensor>> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a zcs checkpoint: bad magic {magic:?}");
    }
    let count = read_u32(&mut f)? as usize;
    if count > 1_000_000 {
        bail!("implausible tensor count {count}");
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let rank = read_u32(&mut f)? as usize;
        if rank > 16 {
            bail!("implausible rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(read_u32(&mut f)? as usize);
        }
        let n: usize = dims.iter().product();
        let mut buf = vec![0u8; 4 * n];
        f.read_exact(&mut buf)?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(HostTensor::new(dims, data));
    }
    Ok(out)
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("zcs_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip() {
        let params = vec![
            HostTensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-7, -1e7]),
            HostTensor::new(vec![4], vec![9.0, 8.0, 7.0, 6.0]),
            HostTensor::scalar(42.0),
        ];
        let p = tmp("rt.ckpt");
        save(&p, &params).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, params);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad.ckpt");
        std::fs::write(&p, b"NOTACKPTxxxx").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_truncated_file() {
        let params = vec![HostTensor::new(vec![8], vec![1.0; 8])];
        let p = tmp("trunc.ckpt");
        save(&p, &params).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn empty_param_list_ok() {
        let p = tmp("empty.ckpt");
        save(&p, &[]).unwrap();
        assert_eq!(load(&p).unwrap().len(), 0);
    }
}
