//! Checkpointing: the v2 crash-safe training format and the legacy v1
//! flat-parameter format.
//!
//! **v2** (`ZCSCKPT2`) is the native trainer's format: one
//! [`TrainCheckpoint`] snapshots everything a bit-exact resume needs --
//! the resident f64 weights, the Adam moments, the optimizer timestep,
//! the [`PdeBatcher`](crate::coordinator::batch::PdeBatcher) draw state
//! (a full [`Pcg64Snapshot`], Box-Muller cache included), and the
//! trajectory-determining run metadata ([`CheckpointMeta`]).  The file is
//! magic + version + payload + trailing CRC32 (all little-endian, f64
//! data verbatim), written atomically: serialize to a buffer, write a
//! sibling `*.tmp`, fsync, rename.  A torn, truncated, or bit-flipped
//! file always fails the CRC (or a bounds check) and loads as `Err` --
//! never as a silently wrong resume; `rust/tests/checkpoint_resume.rs`
//! property-tests exactly that.
//!
//! Because the repo's determinism contract makes trajectories invariant
//! in thread count, SIMD width, replica count, and pipelining, those
//! knobs are recorded for information but *not* validated on resume:
//! a checkpoint taken at `--replicas 4` resumes bit-exactly at
//! `--replicas 1` and vice versa.  Everything that *does* determine the
//! trajectory (problem, strategy, optimizer, sizes, lr, seed, bank) is
//! validated field by field with a typed error.
//!
//! **v1** (`ZCSCKPT1`) is the legacy f32 flat-parameter format of the
//! PJRT artifact path, kept readable for artifact tests; its loader
//! bounds every header field and the payload length before allocating.

use crate::coordinator::error::TrainError;
use crate::rng::Pcg64Snapshot;
use crate::runtime::HostTensor;
use crate::tensor::Tensor;
use crate::util::env::{FaultCell, FaultKind};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 8] = b"ZCSCKPT1";
const MAGIC_V2: &[u8; 8] = b"ZCSCKPT2";
const VERSION_V2: u32 = 2;

/// Header sanity bounds: a real checkpoint is four small MLP weight
/// matrices, so anything past these is a corrupt or hostile file.
const MAX_TENSORS: usize = 4096;
const MAX_RANK: usize = 8;
const MAX_STRING: usize = 256;

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) -- hand-rolled because the
// crate is pure std + anyhow.  Detects every single-bit flip and every
// truncation that survives the length checks.

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC32 of a byte slice (IEEE, the zlib/PNG polynomial).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------------
// v2: versioned bit-exact training checkpoints

/// The trajectory-determining configuration a v2 checkpoint was taken
/// under.  Every field except the last three must match the resuming
/// run's configuration bit for bit ([`CheckpointMeta::validate`]);
/// `replicas`, `threads`, and `simd` are informational -- the
/// determinism contract makes them invisible to the trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointMeta {
    pub problem: String,
    pub strategy: String,
    pub optimizer: String,
    pub m: u64,
    pub n: u64,
    pub n_bc: u64,
    pub q: u64,
    pub hidden: u64,
    pub k: u64,
    pub lr: f64,
    pub seed: u64,
    pub bank_size: u64,
    pub bank_grid: u64,
    /// informational: replica count of the run that wrote the checkpoint
    pub replicas: u64,
    /// informational: thread budget of the writing run
    pub threads: u64,
    /// informational: resolved SIMD level name of the writing run
    pub simd: String,
}

impl CheckpointMeta {
    /// Check a resuming run's meta against this checkpoint's, naming the
    /// first mismatched trajectory-determining field in a typed
    /// [`TrainError::Checkpoint`].
    pub fn validate(&self, current: &CheckpointMeta) -> Result<(), TrainError> {
        let mismatch = |field: &str, have: &str, want: &str| {
            Err(TrainError::Checkpoint {
                reason: format!(
                    "checkpoint was taken under {field}={want}, this run has {field}={have}"
                ),
            })
        };
        macro_rules! check {
            ($field:ident) => {
                if self.$field != current.$field {
                    return mismatch(
                        stringify!($field),
                        &current.$field.to_string(),
                        &self.$field.to_string(),
                    );
                }
            };
        }
        check!(problem);
        check!(strategy);
        check!(optimizer);
        check!(m);
        check!(n);
        check!(n_bc);
        check!(q);
        check!(hidden);
        check!(k);
        check!(seed);
        check!(bank_size);
        check!(bank_grid);
        if self.lr.to_bits() != current.lr.to_bits() {
            return mismatch("lr", &current.lr.to_string(), &self.lr.to_string());
        }
        Ok(())
    }
}

/// One v2 checkpoint: everything a bit-exact resume needs.
#[derive(Clone, Debug)]
pub struct TrainCheckpoint {
    pub meta: CheckpointMeta,
    /// completed training steps at the time of the snapshot
    pub step: u64,
    /// optimizer timestep (== `step` today, but stored separately so the
    /// Adam bias correction can never drift from the weights)
    pub opt_t: u64,
    /// the batcher's draw state *after* `step` batches were drawn
    pub rng: Pcg64Snapshot,
    /// the weight tensors, in the canonical (wb, wb2, wt, wt2) order
    pub weights: Vec<Tensor>,
    /// per-weight Adam (m, v) pairs, aligned with `weights`; empty for
    /// SGD
    pub moments: Vec<(Tensor, Tensor)>,
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_string(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_tensor(buf: &mut Vec<u8>, t: &Tensor) {
    put_u32(buf, t.shape().len() as u32);
    for &d in t.shape() {
        put_u32(buf, d as u32);
    }
    for &v in t.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Serialize a v2 checkpoint to its on-disk bytes (CRC included).
pub fn encode_train(ckpt: &TrainCheckpoint) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC_V2);
    put_u32(&mut buf, VERSION_V2);
    let m = &ckpt.meta;
    put_string(&mut buf, &m.problem);
    put_string(&mut buf, &m.strategy);
    put_string(&mut buf, &m.optimizer);
    put_string(&mut buf, &m.simd);
    for v in [
        m.m, m.n, m.n_bc, m.q, m.hidden, m.k, m.seed, m.bank_size, m.bank_grid, m.replicas,
        m.threads,
    ] {
        put_u64(&mut buf, v);
    }
    buf.extend_from_slice(&m.lr.to_le_bytes());
    put_u64(&mut buf, ckpt.step);
    put_u64(&mut buf, ckpt.opt_t);
    buf.extend_from_slice(&ckpt.rng.state.to_le_bytes());
    buf.extend_from_slice(&ckpt.rng.inc.to_le_bytes());
    buf.push(ckpt.rng.cached.is_some() as u8);
    buf.extend_from_slice(&ckpt.rng.cached.unwrap_or(0.0).to_le_bytes());
    put_u32(&mut buf, ckpt.weights.len() as u32);
    for w in &ckpt.weights {
        put_tensor(&mut buf, w);
    }
    put_u32(&mut buf, ckpt.moments.len() as u32);
    for (m, v) in &ckpt.moments {
        put_tensor(&mut buf, m);
        put_tensor(&mut buf, v);
    }
    let crc = crc32(&buf);
    put_u32(&mut buf, crc);
    buf
}

/// Save a v2 checkpoint atomically: serialize, write a sibling `*.tmp`,
/// fsync, rename.  A crash at any point leaves either the previous
/// checkpoint or a complete new one -- never a torn file under the final
/// name.  `fault` injects a torn write ([`FaultKind::TornCkpt`]) when its
/// step matches, exercising the loader's rejection path.
pub fn save_train(
    path: impl AsRef<Path>,
    ckpt: &TrainCheckpoint,
    fault: Option<&FaultCell>,
) -> Result<()> {
    let path = path.as_ref();
    let mut bytes = encode_train(ckpt);
    if fault.is_some_and(|f| f.should_fire(FaultKind::TornCkpt, ckpt.step)) {
        // simulate a crash mid-write: half the file, CRC long gone
        bytes.truncate(bytes.len() / 2);
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating checkpoint temp file {tmp:?}"))?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} into place as {path:?}"))?;
    Ok(())
}

/// Bounds-checked little-endian reader over a byte slice: every read is
/// length-checked, so a truncated payload becomes a clean `Err` instead
/// of a short read or an unchecked allocation.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("checkpoint truncated: {what} wants {n} bytes, {} left", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8, what)?.try_into().unwrap()))
    }

    fn u128(&mut self, what: &str) -> Result<u128> {
        Ok(u128::from_le_bytes(self.bytes(16, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8, what)?.try_into().unwrap()))
    }

    fn string(&mut self, what: &str) -> Result<String> {
        let len = self.u32(what)? as usize;
        if len > MAX_STRING {
            bail!("implausible {what} length {len}");
        }
        let s = self.bytes(len, what)?;
        String::from_utf8(s.to_vec()).with_context(|| format!("{what} is not utf-8"))
    }

    fn tensor(&mut self, what: &str) -> Result<Tensor> {
        let rank = self.u32(what)? as usize;
        if rank > MAX_RANK {
            bail!("implausible rank {rank} for {what}");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(self.u32(what)? as usize);
        }
        let n = dims
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .with_context(|| format!("dimension overflow in {what}: {dims:?}"))?;
        // bound the element count by the bytes actually present *before*
        // allocating, so a hostile header cannot trigger a huge alloc
        if n > self.remaining() / 8 {
            bail!(
                "checkpoint truncated: {what} claims {n} elements, only {} bytes left",
                self.remaining()
            );
        }
        let data: Vec<f64> = self
            .bytes(8 * n, what)?
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Tensor::new(&dims, data))
    }
}

/// Load a v2 checkpoint: verify magic, version, and the trailing CRC32
/// first, then parse with every header field bounds-checked.  Any
/// truncation or bit flip yields `Err`.
pub fn load_train(path: impl AsRef<Path>) -> Result<TrainCheckpoint> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("reading checkpoint {path:?}"))?;
    decode_train(&bytes).with_context(|| format!("loading checkpoint {path:?}"))
}

/// Decode v2 checkpoint bytes (see [`load_train`]).
pub fn decode_train(bytes: &[u8]) -> Result<TrainCheckpoint> {
    if bytes.len() < MAGIC_V2.len() + 4 + 4 {
        bail!("checkpoint truncated: {} bytes is shorter than any valid file", bytes.len());
    }
    if &bytes[..8] != MAGIC_V2 {
        bail!("not a v2 checkpoint: bad magic {:?}", &bytes[..8]);
    }
    let (payload, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let computed = crc32(payload);
    if stored != computed {
        bail!("checkpoint CRC mismatch: stored {stored:#010x}, computed {computed:#010x}");
    }
    let mut c = Cursor::new(&payload[8..]);
    let version = c.u32("version")?;
    if version != VERSION_V2 {
        bail!("unsupported checkpoint version {version} (this build reads {VERSION_V2})");
    }
    let problem = c.string("problem")?;
    let strategy = c.string("strategy")?;
    let optimizer = c.string("optimizer")?;
    let simd = c.string("simd")?;
    let mut nums = [0u64; 11];
    for (i, v) in nums.iter_mut().enumerate() {
        *v = c.u64(&format!("meta field {i}"))?;
    }
    let [m, n, n_bc, q, hidden, k, seed, bank_size, bank_grid, replicas, threads] = nums;
    let lr = c.f64("lr")?;
    let step = c.u64("step")?;
    let opt_t = c.u64("opt_t")?;
    let state = c.u128("rng state")?;
    let inc = c.u128("rng inc")?;
    let has_cached = c.u8("rng cache flag")?;
    let cached_val = c.f64("rng cache")?;
    if has_cached > 1 {
        bail!("corrupt rng cache flag {has_cached}");
    }
    let rng = Pcg64Snapshot {
        state,
        inc,
        cached: (has_cached == 1).then_some(cached_val),
    };
    let n_weights = c.u32("weight count")? as usize;
    if n_weights > MAX_TENSORS {
        bail!("implausible weight count {n_weights}");
    }
    let mut weights = Vec::with_capacity(n_weights);
    for i in 0..n_weights {
        weights.push(c.tensor(&format!("weight {i}"))?);
    }
    let n_moments = c.u32("moment count")? as usize;
    if n_moments > MAX_TENSORS {
        bail!("implausible moment count {n_moments}");
    }
    if n_moments != 0 && n_moments != n_weights {
        bail!("moment count {n_moments} does not match weight count {n_weights}");
    }
    let mut moments = Vec::with_capacity(n_moments);
    for i in 0..n_moments {
        let m_t = c.tensor(&format!("adam m {i}"))?;
        let v_t = c.tensor(&format!("adam v {i}"))?;
        if m_t.shape() != weights[i].shape() || v_t.shape() != weights[i].shape() {
            bail!("adam moment {i} shape does not match its weight");
        }
        moments.push((m_t, v_t));
    }
    if c.remaining() != 0 {
        bail!("checkpoint has {} trailing bytes", c.remaining());
    }
    Ok(TrainCheckpoint {
        meta: CheckpointMeta {
            problem,
            strategy,
            optimizer,
            m,
            n,
            n_bc,
            q,
            hidden,
            k,
            lr,
            seed,
            bank_size,
            bank_grid,
            replicas,
            threads,
            simd,
        },
        step,
        opt_t,
        rng,
        weights,
        moments,
    })
}

// ---------------------------------------------------------------------------
// v1: legacy f32 flat-parameter format (PJRT artifact path)

/// Save the flat parameter tuple (legacy v1, f32).
pub fn save(path: impl AsRef<Path>, params: &[HostTensor]) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for t in params {
        f.write_all(&(t.dims.len() as u32).to_le_bytes())?;
        for &d in &t.dims {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        for &v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load a legacy v1 checkpoint.  The whole file is read up front and
/// parsed through the same bounds-checked cursor as v2: tensor count,
/// rank, and the dims product are all validated against the bytes
/// actually present before anything is allocated, so an oversized or
/// truncated header errors instead of allocating unchecked or reading
/// short.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<HostTensor>> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("opening {path:?}"))?;
    // decode errors name the offending file, so a bad checkpoint is
    // diagnosable straight from a registry or serve log line
    decode_v1(&bytes).with_context(|| format!("loading checkpoint {path:?}"))
}

fn decode_v1(bytes: &[u8]) -> Result<Vec<HostTensor>> {
    if bytes.len() < MAGIC.len() + 4 {
        bail!("checkpoint truncated: {} bytes is shorter than any valid file", bytes.len());
    }
    if &bytes[..8] != MAGIC {
        bail!("not a zcs checkpoint: bad magic {:?}", &bytes[..8]);
    }
    let mut c = Cursor::new(&bytes[8..]);
    let count = c.u32("tensor count")? as usize;
    if count > MAX_TENSORS {
        bail!("implausible tensor count {count}");
    }
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let what = format!("tensor {i}");
        let rank = c.u32(&what)? as usize;
        if rank > MAX_RANK {
            bail!("implausible rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(c.u32(&what)? as usize);
        }
        let n = dims
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .with_context(|| format!("dimension overflow in {what}: {dims:?}"))?;
        if n > c.remaining() / 4 {
            bail!(
                "checkpoint truncated: {what} claims {n} elements, only {} bytes left",
                c.remaining()
            );
        }
        let data: Vec<f32> = c
            .bytes(4 * n, &what)?
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        out.push(HostTensor::new(dims, data));
    }
    if c.remaining() != 0 {
        bail!("checkpoint has {} trailing bytes", c.remaining());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("zcs_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip() {
        let params = vec![
            HostTensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-7, -1e7]),
            HostTensor::new(vec![4], vec![9.0, 8.0, 7.0, 6.0]),
            HostTensor::scalar(42.0),
        ];
        let p = tmp("rt.ckpt");
        save(&p, &params).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, params);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad.ckpt");
        std::fs::write(&p, b"NOTACKPTxxxx").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_truncated_file() {
        let params = vec![HostTensor::new(vec![8], vec![1.0; 8])];
        let p = tmp("trunc.ckpt");
        save(&p, &params).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn empty_param_list_ok() {
        let p = tmp("empty.ckpt");
        save(&p, &[]).unwrap();
        assert_eq!(load(&p).unwrap().len(), 0);
    }

    #[test]
    fn v1_rejects_oversized_headers_without_allocating() {
        // count = u32::MAX: bounded by MAX_TENSORS, not trusted
        let mut f = Vec::new();
        f.extend_from_slice(MAGIC);
        f.extend_from_slice(&u32::MAX.to_le_bytes());
        let p = tmp("hostile_count.ckpt");
        std::fs::write(&p, &f).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("implausible tensor count"), "{err}");

        // rank = 10_000: bounded by MAX_RANK
        let mut f = Vec::new();
        f.extend_from_slice(MAGIC);
        f.extend_from_slice(&1u32.to_le_bytes());
        f.extend_from_slice(&10_000u32.to_le_bytes());
        let p = tmp("hostile_rank.ckpt");
        std::fs::write(&p, &f).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("implausible rank"), "{err}");

        // dims whose product overflows usize: checked multiply, clear error
        let mut f = Vec::new();
        f.extend_from_slice(MAGIC);
        f.extend_from_slice(&1u32.to_le_bytes());
        f.extend_from_slice(&4u32.to_le_bytes());
        for _ in 0..4 {
            f.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        let p = tmp("hostile_overflow.ckpt");
        std::fs::write(&p, &f).unwrap();
        let err = format!("{:#}", load(&p).unwrap_err());
        assert!(err.contains("overflow"), "{err}");

        // plausible dims but no payload: bounded by the bytes present
        let mut f = Vec::new();
        f.extend_from_slice(MAGIC);
        f.extend_from_slice(&1u32.to_le_bytes());
        f.extend_from_slice(&2u32.to_le_bytes());
        f.extend_from_slice(&1000u32.to_le_bytes());
        f.extend_from_slice(&1000u32.to_le_bytes());
        let p = tmp("hostile_short.ckpt");
        std::fs::write(&p, &f).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn v1_rejects_trailing_garbage() {
        let params = vec![HostTensor::new(vec![2], vec![1.0, 2.0])];
        let p = tmp("trailing.ckpt");
        save(&p, &params).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
    }

    fn sample_v2(adam: bool) -> TrainCheckpoint {
        TrainCheckpoint {
            meta: CheckpointMeta {
                problem: "antiderivative".into(),
                strategy: "zcs".into(),
                optimizer: if adam { "adam" } else { "sgd" }.into(),
                m: 2,
                n: 6,
                n_bc: 4,
                q: 5,
                hidden: 8,
                k: 4,
                lr: 5e-3,
                seed: 7,
                bank_size: 8,
                bank_grid: 32,
                replicas: 2,
                threads: 4,
                simd: "avx2".into(),
            },
            step: 17,
            opt_t: 17,
            rng: Pcg64Snapshot {
                state: 0x0123_4567_89ab_cdef_u128 << 17,
                inc: 77,
                cached: Some(-0.25),
            },
            weights: vec![
                Tensor::new(&[2, 3], vec![1.0, -0.0, f64::MIN_POSITIVE, 3.5, -2.0, 1e300]),
                Tensor::new(&[3], vec![0.1, 0.2, 0.3]),
            ],
            moments: if adam {
                vec![
                    (Tensor::zeros(&[2, 3]), Tensor::new(&[2, 3], vec![1e-9; 6])),
                    (Tensor::new(&[3], vec![4.0, 5.0, 6.0]), Tensor::zeros(&[3])),
                ]
            } else {
                Vec::new()
            },
        }
    }

    #[test]
    fn v2_round_trip_is_bit_exact() {
        for adam in [false, true] {
            let ckpt = sample_v2(adam);
            let p = tmp(if adam { "v2_adam.ckpt" } else { "v2_sgd.ckpt" });
            save_train(&p, &ckpt, None).unwrap();
            let back = load_train(&p).unwrap();
            assert_eq!(back.meta, ckpt.meta);
            assert_eq!(back.step, ckpt.step);
            assert_eq!(back.opt_t, ckpt.opt_t);
            assert_eq!(back.rng, ckpt.rng);
            assert_eq!(back.weights.len(), ckpt.weights.len());
            for (a, b) in back.weights.iter().zip(&ckpt.weights) {
                assert_eq!(a.shape(), b.shape());
                let ab: Vec<u64> = a.data().iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u64> = b.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(ab, bb, "weights must round-trip bit for bit");
            }
            assert_eq!(back.moments.len(), ckpt.moments.len());
        }
    }

    #[test]
    fn v2_rejects_v1_magic_and_vice_versa() {
        let ckpt = sample_v2(false);
        let p = tmp("v2_cross.ckpt");
        save_train(&p, &ckpt, None).unwrap();
        assert!(load(&p).is_err(), "v1 loader must refuse a v2 file");
        let p1 = tmp("v1_cross.ckpt");
        save(&p1, &[HostTensor::scalar(1.0)]).unwrap();
        assert!(load_train(&p1).is_err(), "v2 loader must refuse a v1 file");
    }

    #[test]
    fn v2_rejects_any_truncation() {
        let bytes = encode_train(&sample_v2(true));
        for cut in [0, 1, 7, 8, 11, 12, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_train(&bytes[..cut]).is_err(), "truncation to {cut} must fail");
        }
    }

    #[test]
    fn v2_rejects_single_bit_flips() {
        let bytes = encode_train(&sample_v2(true));
        // a few scattered positions incl. header, payload, and CRC itself
        for pos in [0usize, 8, 12, 40, bytes.len() / 2, bytes.len() - 5, bytes.len() - 1] {
            let mut evil = bytes.clone();
            evil[pos] ^= 0x10;
            assert!(decode_train(&evil).is_err(), "bit flip at {pos} must fail");
        }
    }

    #[test]
    fn v2_meta_validation_names_the_field() {
        let a = sample_v2(false).meta;
        let mut b = a.clone();
        b.seed = 999;
        let err = a.validate(&b).unwrap_err().to_string();
        assert!(err.contains("seed"), "{err}");
        let mut c = a.clone();
        c.lr = 1e-2;
        let err = a.validate(&c).unwrap_err().to_string();
        assert!(err.contains("lr"), "{err}");
        // informational fields never block a resume
        let mut d = a.clone();
        d.replicas = 64;
        d.threads = 128;
        d.simd = "off".into();
        a.validate(&d).unwrap();
    }

    #[test]
    fn torn_ckpt_fault_produces_an_unloadable_file() {
        use crate::util::env::{FaultSpec, FaultKind};
        let ckpt = sample_v2(true);
        let cell = FaultCell::new(FaultSpec { kind: FaultKind::TornCkpt, step: ckpt.step });
        let p = tmp("torn.ckpt");
        save_train(&p, &ckpt, Some(&cell)).unwrap();
        assert!(load_train(&p).is_err(), "torn write must not load");
        // the fault fired once; the retry writes a good file
        save_train(&p, &ckpt, Some(&cell)).unwrap();
        assert!(load_train(&p).is_ok());
    }

    #[test]
    fn load_errors_name_the_file_and_the_checksums() {
        // CRC mismatch: the chain names the path and both checksums, so
        // a registry load failure is diagnosable from one serve log line
        let ckpt = sample_v2(false);
        let p = tmp("diag.ckpt");
        save_train(&p, &ckpt, None).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", load_train(&p).unwrap_err());
        assert!(err.contains("diag.ckpt"), "{err}");
        assert!(err.contains("CRC mismatch"), "{err}");
        assert!(err.contains("stored") && err.contains("computed"), "{err}");

        // v1 decode errors carry the path too
        let p1 = tmp("diag_v1.ckpt");
        std::fs::write(&p1, b"NOTACKPTxxxx").unwrap();
        let err = format!("{:#}", load(&p1).unwrap_err());
        assert!(err.contains("diag_v1.ckpt"), "{err}");
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // standard IEEE CRC32 check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
