//! Data-parallel replica executors for the native training path.
//!
//! A [`ReplicaSet`] shards the *function* dimension (the paper's M) of
//! one training step across N replica executors.  The batch is always
//! decomposed into the canonical [`lane_count`] lane blocks -- a property
//! of the problem size, never of N -- and each replica compiles its own
//! resident step [`Program`] over a contiguous run of those lanes
//! ([`Program::attach_optimizer_replicated`]).  Per step, every replica
//! runs forward + backward over its own function rows only, then its
//! in-Program `grad-allreduce` instructions meet the group at a barrier
//! and fold *all* lanes' gradients in one fixed ascending order, so each
//! replica applies the identical reduced gradient to its own copy of the
//! resident weights.  No gradient ever crosses the host boundary: the
//! reduce reads peer arena slots through the [`ReplicaComm`] pointer
//! table and accumulates with the same multiply-then-add `axpy` kernel
//! at every width and thread count.
//!
//! Determinism contract: because the lane decomposition and the fold
//! order are invariant in N, an N-replica run is **bit-identical** to a
//! single replica executing the same lanes back to back -- losses and
//! final weights alike (`rust/tests/replica_train.rs` pins every native
//! problem x strategy x optimizer at 1, 2, and 4 replicas).
//!
//! Threading: the parent thread budget ([`NativeRunConfig::threads`],
//! resolved through `ZCS_THREADS`) is split evenly across replicas, each
//! of which owns a persistent [`crate::util::pool::Pool`]; replica 0 (the
//! *lead*) steps inline on the training thread while replicas 1.. are
//! driven by parked helper threads woken once per step.  The feed-based
//! fallback (`resident: false`) keeps weights host-side and therefore
//! always runs single-replica, folding its lane gradients with the same
//! serial `axpy` schedule.
//!
//! [`lane_count`]: crate::pde::residual::lane_count

use crate::autodiff::{
    Executor, NodeId, ProfileReport, Program, ReplicaComm, SanitizeTrip, SchedMode,
    BARRIER_POISON_MSG, BARRIER_STALL_MSG,
};
use crate::coordinator::batch::PdeBatch;
use crate::coordinator::error::{panic_text, TrainError};
use crate::coordinator::native::{NativeRunConfig, Optimizer};
use crate::hlostats::{analyze_program, ProgramReport};
use crate::pde::residual::{
    build_lane_training_problem, init_weights, lane_bounds, lane_count, BlockSizes,
};
use crate::tensor::kernels;
use crate::tensor::simd::SimdLevel;
use crate::tensor::Tensor;
use crate::util::env::{FaultCell, FaultKind};
use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Where one replica-program input comes from on the per-step fast path
/// (the lane-blocked analogue of the trainer's single-program feed plan).
#[derive(Clone, Copy, Debug)]
enum LaneFeedSrc {
    /// index into the host weight vector (feed-based fallback only:
    /// resident programs read weights from executor state instead)
    Weight(usize),
    /// the sensor matrix of local lane shard `j`
    Sensor(usize),
    /// named feed `k` of local lane shard `j` (shards arrive in the
    /// batcher's registration order, which is the lane builder's order)
    Feed(usize, usize),
    /// index into the flattened constant extras (ZCS `z` and `a`)
    Extra(usize),
}

/// One replica's compiled step program, executor, and per-step buffers.
struct ReplicaEngine {
    program: Program,
    exec: Executor,
    /// global lane indices this replica owns, ascending
    local_lanes: Vec<usize>,
    /// function-row range of each local lane, aligned with `local_lanes`
    rows: Vec<(usize, usize)>,
    /// one shard of the global batch per local lane, refilled in place
    /// every step (allocation-free after warmup)
    shards: Vec<PdeBatch>,
    /// one source per [`Program::inputs`] entry, resolved at build time
    feed_plan: Vec<LaneFeedSrc>,
    /// reusable feed buffer (raw pointers so its capacity persists; only
    /// populated inside one step call, cleared before it returns)
    feed_scratch: Vec<*const Tensor>,
    /// constant extra inputs of all local lanes, flattened
    extras: Vec<Tensor>,
    /// lane-major `[loss, loss_pde, loss_bc]` readback, 3 per local lane
    losses: Vec<f64>,
    /// injected-panic fault, armed on the set's last replica only
    fault: Option<Arc<FaultCell>>,
    /// resident steps this engine has run (the injected fault's clock)
    local_step: u64,
    /// how long an injected [`FaultKind::Stall`] parks this replica:
    /// twice the watchdog deadline (so an armed watchdog always fires
    /// first), capped so the sleep stays bounded with the watchdog off
    stall_sleep: Duration,
}

// SAFETY: the only non-`Send` fields are raw-pointer scratch buffers --
// `feed_scratch` here and the executor's operand scratch -- and both are
// strictly call-local: populated and drained inside a single step, so the
// engine only ever moves between threads while they hold no live
// pointers.  Everything else is owned data or `Send + Sync` `Arc`s.
unsafe impl Send for ReplicaEngine {}

impl ReplicaEngine {
    /// Refill this replica's per-lane shards from the global batch.
    fn fill(&mut self, batch: &PdeBatch) {
        for (rows, shard) in self.rows.iter().zip(&mut self.shards) {
            batch.shard_into(*rows, shard);
        }
    }

    /// Resolve the feed plan into program-input order (no hashing, no
    /// clones; `weights` is empty on the resident path).
    fn feed_refs(&mut self, weights: &[Tensor]) {
        self.feed_scratch.clear();
        for src in &self.feed_plan {
            let t: &Tensor = match *src {
                LaneFeedSrc::Weight(i) => &weights[i],
                LaneFeedSrc::Sensor(j) => &self.shards[j].p,
                LaneFeedSrc::Feed(j, k) => &self.shards[j].feeds[k].1,
                LaneFeedSrc::Extra(i) => &self.extras[i],
            };
            self.feed_scratch.push(t as *const Tensor);
        }
    }

    /// One resident step over the already-filled shards: blocks at the
    /// group barriers inside the `grad-allreduce` instructions until
    /// every replica has folded, leaving the lane losses in `self.losses`.
    fn step_resident(&mut self) {
        self.local_step += 1;
        if let Some(cell) = &self.fault {
            if cell.should_fire(FaultKind::Panic, self.local_step) {
                panic!("zcs injected fault: replica worker panic at step {}", self.local_step);
            }
            if cell.should_fire(FaultKind::Stall, self.local_step) {
                // park past an armed watchdog's deadline; bounded even
                // with the watchdog off, so a mis-configured run hangs
                // for one sleep, not forever
                std::thread::sleep(self.stall_sleep);
            }
        }
        self.feed_refs(&[]);
        // SAFETY: `&Tensor` and `*const Tensor` have identical layout;
        // every pointee (shards, extras) lives in `self`, outlives this
        // call, and is not mutated while the executor borrows it
        let ins: &[&Tensor] = unsafe {
            std::slice::from_raw_parts(
                self.feed_scratch.as_ptr() as *const &Tensor,
                self.feed_scratch.len(),
            )
        };
        self.exec.run_scalars(&self.program, ins, &mut self.losses);
        self.feed_scratch.clear();
    }

    /// One feed-based run over the filled shards: returns the program
    /// outputs (lane-major losses, then weight-major per-lane gradients).
    fn step_fallback(&mut self, weights: &[Tensor]) -> Vec<Tensor> {
        self.feed_refs(weights);
        // SAFETY: as in `step_resident`; `weights` additionally outlives
        // the call and is disjoint from everything the executor writes
        let ins: &[&Tensor] = unsafe {
            std::slice::from_raw_parts(
                self.feed_scratch.as_ptr() as *const &Tensor,
                self.feed_scratch.len(),
            )
        };
        let outs = self.exec.run_inputs(&self.program, ins);
        self.feed_scratch.clear();
        outs
    }
}

/// Command mailbox state of one parked replica driver.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Cmd {
    Idle,
    Step,
    Exit,
}

struct SlotState {
    /// parked engine; taken out by the driver for the duration of a step
    engine: Option<ReplicaEngine>,
    cmd: Cmd,
    /// the last commanded step has finished and `engine` is parked again
    done: bool,
    /// the last commanded step panicked; payload text for the lead
    panicked: Option<String>,
}

/// Mailbox through which the training thread commands one helper-driven
/// replica (replicas 1..; the lead steps inline).
struct ReplicaSlot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

/// Helper-thread loop: wait for a step command, run it (blocking at the
/// group barriers with the other replicas), park the engine again.
///
/// Panic safety: the step runs under `catch_unwind`, so a dying replica
/// (1) poisons the group barrier -- waking every peer blocked in the
/// gradient all-reduce instead of deadlocking them -- and (2) parks its
/// engine with `panicked` set, so the lead surfaces a typed
/// [`TrainError::WorkerPanic`] after the whole group has unwound.  The
/// driver thread itself survives and keeps serving commands: a panicking
/// step leaves the resident state untouched (the in-Program optimizer
/// updates run strictly after the all-reduce barriers), so the step can
/// simply be retried.
fn replica_driver(slot: &ReplicaSlot) {
    loop {
        let mut engine = {
            let mut st = slot.state.lock().unwrap();
            loop {
                match st.cmd {
                    Cmd::Idle => st = slot.cv.wait(st).unwrap(),
                    Cmd::Exit => return,
                    Cmd::Step => break,
                }
            }
            st.cmd = Cmd::Idle;
            st.engine.take().expect("replica engine missing at step")
        };
        let outcome = catch_unwind(AssertUnwindSafe(|| engine.step_resident()));
        let panicked = outcome.err().map(|payload| {
            // wake peers blocked at the all-reduce before parking
            engine.feed_scratch.clear();
            engine.exec.poison_comm();
            panic_text(payload)
        });
        let mut st = slot.state.lock().unwrap();
        st.engine = Some(engine);
        st.panicked = panicked;
        st.done = true;
        slot.cv.notify_all();
    }
}

/// N data-parallel replica executors stepping one sharded batch in lock
/// step (see the module doc).  Constructed by
/// [`NativeTrainer`](crate::coordinator::native::NativeTrainer) whenever
/// the problem has more than one function; a single-replica set folds
/// all lanes locally and involves no threads or barriers beyond its own
/// kernel pool.
pub struct ReplicaSet {
    /// replica 0, stepped inline on the training thread
    lead: ReplicaEngine,
    /// replicas 1.., each parked behind its driver thread's mailbox
    others: Vec<Arc<ReplicaSlot>>,
    drivers: Vec<JoinHandle<()>>,
    /// the group's gradient-reduce channel (None when single-replica);
    /// held so a poisoned barrier can be reset between steps
    comm: Option<Arc<ReplicaComm>>,
    /// deterministic fault injector shared with the engines
    fault: Option<Arc<FaultCell>>,
    n_lanes: usize,
    n_replicas: usize,
    n_weights: usize,
    /// total kernel-thread budget (what [`ReplicaSet::threads`] reports)
    budget: usize,
    per_replica_threads: usize,
    resident: bool,
    optimizer: Optimizer,
    lr: f64,
    /// fallback path only -- resident weights live in executor state
    host_weights: Vec<Tensor>,
    /// host-side Adam (m, v) pairs -- fallback path only
    host_moments: Vec<(Tensor, Tensor)>,
    /// host-side optimizer timestep -- fallback path only
    host_t: u64,
    /// fallback gradient accumulators, one per weight, reused every step
    grad_scratch: Vec<Tensor>,
    /// per-global-lane `[loss, loss_pde, loss_bc]` staging for the fold
    lane_losses: Vec<[f64; 3]>,
    coord_dim: usize,
    compile_time: Duration,
    /// `Some(deadline)` when the dynamic sanitizer armed the step
    /// watchdogs: the lead's wait for replica parking times out after
    /// this and poisons the barrier so a stuck replica unwinds
    stall: Option<Duration>,
}

impl ReplicaSet {
    /// Compile one step program per replica and park the helper drivers.
    /// The replica count is `config.replicas` (0 = `ZCS_REPLICAS`, else
    /// 1), clamped to the lane count; the feed-based fallback always runs
    /// single-replica.
    pub fn new(config: &NativeRunConfig) -> Result<ReplicaSet> {
        ensure!(config.m >= 1 && config.n >= 1 && config.q >= 1, "empty problem");
        let n_lanes = lane_count(config.m);
        let requested = if config.replicas == 0 {
            crate::util::env::default_replicas()
        } else {
            config.replicas
        };
        let n_replicas = if config.resident { requested.clamp(1, n_lanes) } else { 1 };
        let budget = if config.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            config.threads
        };
        let per_replica_threads = (budget / n_replicas).max(1);

        let t0 = Instant::now();
        let mut comm: Option<Arc<ReplicaComm>> = None;
        let mut engines = Vec::with_capacity(n_replicas);
        let mut host_weights = Vec::new();
        let mut n_weights = 0;
        let mut coord_dim = 0;
        for r in 0..n_replicas {
            let (l0, l1) = lane_bounds(n_lanes, n_replicas, r);
            let local_lanes: Vec<usize> = (l0..l1).collect();
            let built = build_lane_training_problem(
                config.problem,
                config.strategy,
                config.m,
                &local_lanes,
                config.q,
                config.hidden,
                config.k,
                BlockSizes { n_in: config.n, n_bc: config.n_bc },
            )?;
            let mut program = Program::compile(&built.graph, &built.outputs);
            if config.resident {
                program = program.attach_optimizer_replicated(
                    &built.weight_ids,
                    config.optimizer.rule(config.lr),
                    n_lanes,
                    &local_lanes,
                );
            }
            if config.sanitize.verify() {
                program.verify().map_err(|e| {
                    anyhow!("replica {r} step program failed verification: {e}")
                })?;
            }
            // every replica draws the identical init (same seed, same
            // shapes), so their resident weight copies never diverge
            let weights = init_weights(&built.graph, &built.weight_ids, config.seed);
            n_weights = built.weight_ids.len();
            if comm.is_none() && n_replicas > 1 {
                let stall = config
                    .sanitize
                    .dynamic()
                    .then(|| Duration::from_millis(config.stall_ms.max(1)));
                comm = Some(Arc::new(
                    ReplicaComm::new(n_weights, n_lanes, n_replicas).with_stall(stall),
                ));
            }

            let mut src_of: HashMap<NodeId, LaneFeedSrc> = HashMap::new();
            for (i, id) in built.weight_ids.iter().enumerate() {
                src_of.insert(*id, LaneFeedSrc::Weight(i));
            }
            let mut n_extras = 0;
            for (j, lane) in built.lanes.iter().enumerate() {
                src_of.insert(lane.p, LaneFeedSrc::Sensor(j));
                for (k, (_, id)) in lane.feeds.iter().enumerate() {
                    src_of.insert(*id, LaneFeedSrc::Feed(j, k));
                }
                for (id, _) in &lane.extra_inputs {
                    src_of.insert(*id, LaneFeedSrc::Extra(n_extras));
                    n_extras += 1;
                }
            }
            let feed_plan: Vec<LaneFeedSrc> = program
                .inputs
                .iter()
                .map(|id| {
                    src_of
                        .get(id)
                        .copied()
                        .ok_or_else(|| anyhow!("replica program wants unknown input node {id}"))
                })
                .collect::<Result<_>>()?;

            let mut exec = Executor::with_threads(per_replica_threads)
                .with_sched(config.schedule)
                .with_simd(config.simd);
            exec.set_sanitize(config.sanitize.dynamic());
            if config.profile {
                exec.enable_profiling();
            }
            if r == 0 {
                // NaN injection is armed on the lead only, so exactly one
                // deterministic executor poisons its gradient
                if let Some(cell) = &config.fault {
                    exec.arm_fault(Arc::clone(cell));
                }
            }
            if config.resident {
                exec.bind_states(&program, weights);
            } else {
                host_weights = weights;
            }
            if let Some(comm) = &comm {
                exec.bind_comm(Arc::clone(comm));
            }
            coord_dim = built.coord_dim;

            let rows: Vec<(usize, usize)> = built.lanes.iter().map(|l| l.rows).collect();
            let shards: Vec<PdeBatch> =
                built.lanes.iter().map(|_| PdeBatch::empty()).collect();
            let losses = vec![0.0; 3 * built.lanes.len()];
            let mut extras = Vec::with_capacity(n_extras);
            for lane in built.lanes {
                extras.extend(lane.extra_inputs.into_iter().map(|(_, t)| t));
            }
            engines.push(ReplicaEngine {
                program,
                exec,
                local_lanes,
                rows,
                shards,
                feed_plan,
                feed_scratch: Vec::new(),
                extras,
                losses,
                // the *last* replica carries the injected panic, so a
                // multi-replica set exercises the helper-thread unwind
                fault: if r + 1 == n_replicas { config.fault.clone() } else { None },
                local_step: 0,
                stall_sleep: Duration::from_millis(
                    config.stall_ms.saturating_mul(2).clamp(1, 60_000),
                ),
            });
        }
        let compile_time = t0.elapsed();

        let host_moments = match (config.resident, config.optimizer) {
            (false, Optimizer::Adam) => host_weights
                .iter()
                .map(|w| (Tensor::zeros(w.shape()), Tensor::zeros(w.shape())))
                .collect(),
            _ => Vec::new(),
        };
        let grad_scratch = if config.resident {
            Vec::new()
        } else {
            (0..n_weights).map(|_| Tensor::zeros(&[0])).collect()
        };

        let mut engines = engines.into_iter();
        let lead = engines.next().expect("at least one replica");
        let mut others = Vec::new();
        let mut drivers = Vec::new();
        for (i, engine) in engines.enumerate() {
            let slot = Arc::new(ReplicaSlot {
                state: Mutex::new(SlotState {
                    engine: Some(engine),
                    cmd: Cmd::Idle,
                    done: false,
                    panicked: None,
                }),
                cv: Condvar::new(),
            });
            let driven = Arc::clone(&slot);
            let handle = std::thread::Builder::new()
                .name(format!("zcs-replica{}", i + 1))
                .spawn(move || replica_driver(&driven))
                .expect("spawn replica driver");
            others.push(slot);
            drivers.push(handle);
        }
        Ok(ReplicaSet {
            lead,
            others,
            drivers,
            comm,
            fault: config.fault.clone(),
            n_lanes,
            n_replicas,
            n_weights,
            budget,
            per_replica_threads,
            resident: config.resident,
            optimizer: config.optimizer,
            lr: config.lr,
            host_weights,
            host_moments,
            host_t: 0,
            grad_scratch,
            lane_losses: vec![[0.0; 3]; n_lanes],
            coord_dim,
            compile_time,
            stall: config
                .sanitize
                .dynamic()
                .then(|| Duration::from_millis(config.stall_ms.max(1))),
        })
    }

    /// Drain the first sanitizer trip across every replica executor (the
    /// lead first, then the parked drivers in replica order).
    fn take_trip(&mut self) -> Option<SanitizeTrip> {
        if let Some(t) = self.lead.exec.take_trip() {
            return Some(t);
        }
        for slot in &self.others {
            let mut st = slot.state.lock().unwrap();
            if let Some(engine) = st.engine.as_mut() {
                if let Some(t) = engine.exec.take_trip() {
                    return Some(t);
                }
            }
        }
        None
    }

    /// Map a drained sanitizer trip to its typed error: non-finite trips
    /// surface as the same [`TrainError::NonFinite`] the loss guard
    /// raises (so NaN rollback keeps working) with instruction-level
    /// provenance in the output name; races are executor bugs and get
    /// their own [`TrainError::Sanitizer`] variant.
    fn trip_error(trip: SanitizeTrip, step_no: u64) -> anyhow::Error {
        match trip {
            SanitizeTrip::NonFinite { .. } => TrainError::NonFinite {
                step: step_no,
                output: trip.to_string(),
                value: f64::NAN,
            }
            .into(),
            SanitizeTrip::Race { .. } => {
                TrainError::Sanitizer { step: step_no, what: trip.to_string() }.into()
            }
        }
    }

    /// One optimizer step on one (unsharded) batch; returns
    /// `(loss, loss_pde, loss_bc)` folded over every lane in ascending
    /// order -- the same sum a single replica computes.
    ///
    /// Resident path: shards are refilled in place, replicas 1.. are
    /// woken, the lead steps inline (meeting the others at the gradient
    /// all-reduce barriers), and only loss scalars cross back per lane.
    /// After warmup the training thread performs no heap allocation.
    /// As on the single-program path, a non-finite loss errors *after*
    /// the resident in-program update has run but *before* the fallback
    /// touches its host weights.
    ///
    /// Panic safety: a panicking replica poisons the gradient-reduce
    /// barrier, every peer unwinds out of its own step (caught, engines
    /// parked), and the lead returns a typed
    /// [`TrainError::WorkerPanic`] carrying the root-cause payload.  No
    /// resident state was modified (the in-Program updates run after the
    /// barriers), so the very next [`ReplicaSet::step`] call retries
    /// cleanly on a reset barrier.
    pub fn step(&mut self, batch: &PdeBatch) -> Result<(f64, f64, f64)> {
        if !self.resident {
            return self.step_fallback(batch);
        }
        let step_no = self.lead.exec.opt_steps() + 1;
        if let Some(comm) = &self.comm {
            // every driver is parked between steps, so resetting a
            // poisoned barrier here is race-free
            comm.clear_poison();
        }
        for slot in &self.others {
            let mut st = slot.state.lock().unwrap();
            let engine = st.engine.as_mut().expect("replica engine parked");
            engine.fill(batch);
            st.done = false;
            st.panicked = None;
            st.cmd = Cmd::Step;
            drop(st);
            slot.cv.notify_all();
        }
        self.lead.fill(batch);
        let lead = &mut self.lead;
        let lead_panic = catch_unwind(AssertUnwindSafe(|| lead.step_resident()))
            .err()
            .map(|payload| {
                lead.feed_scratch.clear();
                lead.exec.poison_comm();
                panic_text(payload)
            });
        stash_losses(&mut self.lane_losses, &self.lead);
        let mut panics: Vec<String> = lead_panic.into_iter().collect();
        for slot in &self.others {
            let mut st = slot.state.lock().unwrap();
            while !st.done {
                match self.stall {
                    None => st = slot.cv.wait(st).unwrap(),
                    Some(d) => {
                        // step-completion watchdog: a replica that fails
                        // to park within the deadline gets its barrier
                        // poisoned, converting a stuck all-reduce into
                        // an unwind-and-park we can keep waiting for
                        let (guard, timeout) = slot.cv.wait_timeout(st, d).unwrap();
                        st = guard;
                        if timeout.timed_out() && !st.done {
                            if let Some(comm) = &self.comm {
                                comm.poison();
                            }
                        }
                    }
                }
            }
            if let Some(what) = st.panicked.take() {
                panics.push(what);
            }
            let engine = st.engine.as_ref().expect("replica engine parked");
            stash_losses(&mut self.lane_losses, engine);
        }
        if !panics.is_empty() {
            // the root cause is whichever thread died first; peers that
            // merely unwound from the poisoned barrier are secondary
            let what = panics
                .iter()
                .find(|p| !p.contains(BARRIER_POISON_MSG))
                .unwrap_or(&panics[0])
                .clone();
            if what.contains(BARRIER_STALL_MSG) {
                // the watchdog converted a hang into a panic: surface it
                // as the typed stall, not a generic worker panic
                return Err(TrainError::Stalled { step: step_no, what }.into());
            }
            return Err(TrainError::WorkerPanic { step: step_no, what }.into());
        }
        if self.stall.is_some() {
            if let Some(trip) = self.take_trip() {
                return Err(Self::trip_error(trip, step_no));
            }
        }
        self.fold_losses(step_no)
    }

    /// Feed-based single-replica step: run the lane program with host
    /// weights, fold lane gradients with the serial `axpy` schedule (the
    /// exact fold the in-Program all-reduce performs), update host-side.
    fn step_fallback(&mut self, batch: &PdeBatch) -> Result<(f64, f64, f64)> {
        debug_assert_eq!(self.n_replicas, 1, "the fallback owns every lane");
        let step_no = self.host_t + 1;
        let mut outs = {
            let lead = &mut self.lead;
            let weights = &self.host_weights;
            let fault = self.fault.clone();
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                if let Some(cell) = &fault {
                    if cell.should_fire(FaultKind::Panic, step_no) {
                        panic!("zcs injected fault: fallback step panic at step {step_no}");
                    }
                }
                lead.fill(batch);
                lead.step_fallback(weights)
            }));
            match outcome {
                Ok(outs) => outs,
                Err(payload) => {
                    lead.feed_scratch.clear();
                    return Err(TrainError::WorkerPanic {
                        step: step_no,
                        what: panic_text(payload),
                    }
                    .into());
                }
            }
        };
        if self.stall.is_some() {
            if let Some(trip) = self.lead.exec.take_trip() {
                return Err(Self::trip_error(trip, step_no));
            }
        }
        let kl = self.lead.local_lanes.len();
        for (k, &lane) in self.lead.local_lanes.iter().enumerate() {
            let ls = &outs[3 * k..3 * k + 3];
            self.lane_losses[lane] = [ls[0].data()[0], ls[1].data()[0], ls[2].data()[0]];
        }
        let folded = self.fold_losses(step_no)?;
        if let Some(cell) = &self.fault {
            // fallback NaN injection: poison the first lane gradient
            // before the fold so the guard below trips
            if cell.should_fire(FaultKind::NanGrad, step_no) {
                if let Some(g) = outs.get_mut(3 * kl) {
                    g.data_mut().fill(f64::NAN);
                }
            }
        }
        // copy lane 0's gradient, then axpy each higher lane in ascending
        // order -- multiply-then-add, bit-identical to the resident reduce
        for (w, acc) in self.grad_scratch.iter_mut().enumerate() {
            let base = 3 * kl + w * kl;
            acc.reset(outs[base].shape()).copy_from_slice(outs[base].data());
            for g in &outs[base + 1..base + kl] {
                kernels::axpy_accumulate(acc, g, 1.0);
            }
        }
        // non-finite gradient guard: refuse to commit a poisoned update,
        // leaving the host weights exactly as they were
        for (w, acc) in self.grad_scratch.iter().enumerate() {
            if let Some(&bad) = acc.data().iter().find(|v| !v.is_finite()) {
                return Err(TrainError::NonFinite {
                    step: step_no,
                    output: format!("grad[{w}]"),
                    value: bad,
                }
                .into());
            }
        }
        self.host_t += 1;
        match self.optimizer {
            Optimizer::Sgd => {
                for (w, g) in self.host_weights.iter_mut().zip(&self.grad_scratch) {
                    kernels::sgd_update(w, g, self.lr);
                }
            }
            Optimizer::Adam => {
                for ((w, (m, v)), g) in self
                    .host_weights
                    .iter_mut()
                    .zip(self.host_moments.iter_mut())
                    .zip(&self.grad_scratch)
                {
                    kernels::adam_update(
                        w,
                        m,
                        v,
                        g,
                        self.lr,
                        Optimizer::BETA1,
                        Optimizer::BETA2,
                        Optimizer::EPS,
                        self.host_t,
                    );
                }
            }
        }
        Ok(folded)
    }

    /// Fold the staged per-lane losses in ascending lane order.  A
    /// non-finite component yields a typed [`TrainError::NonFinite`]
    /// naming the output, so divergence reports point at the physics.
    fn fold_losses(&self, step: u64) -> Result<(f64, f64, f64)> {
        let mut total = [0.0f64; 3];
        for lane in &self.lane_losses {
            for (t, v) in total.iter_mut().zip(lane) {
                *t += v;
            }
        }
        for (name, v) in ["loss", "loss_pde", "loss_bc"].into_iter().zip(total) {
            if !v.is_finite() {
                return Err(
                    TrainError::NonFinite { step, output: name.to_string(), value: v }.into()
                );
            }
        }
        Ok((total[0], total[1], total[2]))
    }

    /// Current weights (wb, wb2, wt, wt2).  Every replica holds the same
    /// bits (identical init, identical reduced updates), so the lead's
    /// resident copy speaks for the group.
    pub fn weights(&self) -> &[Tensor] {
        if self.resident {
            &self.lead.exec.states()[..self.n_weights]
        } else {
            &self.host_weights
        }
    }

    /// Snapshot the training state for a checkpoint: the weight tensors,
    /// the per-weight Adam `(m, v)` pairs (empty for SGD), and the
    /// optimizer timestep.  Resident state is read from the lead replica
    /// -- every replica holds the identical bits, so the lead speaks for
    /// the group.
    pub fn export_states(&self) -> (Vec<Tensor>, Vec<(Tensor, Tensor)>, u64) {
        if self.resident {
            let states = self.lead.exec.states();
            let weights = states[..self.n_weights].to_vec();
            let mut moments = Vec::new();
            if self.optimizer == Optimizer::Adam {
                for i in 0..self.n_weights {
                    moments.push((
                        states[self.n_weights + 2 * i].clone(),
                        states[self.n_weights + 2 * i + 1].clone(),
                    ));
                }
            }
            (weights, moments, self.lead.exec.opt_steps())
        } else {
            (self.host_weights.clone(), self.host_moments.clone(), self.host_t)
        }
    }

    /// Restore a checkpointed training state into every replica (or the
    /// host copies, on the fallback path): the subsequent trajectory is
    /// bit-identical to the run that wrote the snapshot.
    pub fn restore_states(
        &mut self,
        weights: &[Tensor],
        moments: &[(Tensor, Tensor)],
        opt_t: u64,
    ) -> Result<()> {
        ensure!(
            weights.len() == self.n_weights,
            "checkpoint has {} weights, this problem has {}",
            weights.len(),
            self.n_weights
        );
        let want_moments = if self.optimizer == Optimizer::Adam { self.n_weights } else { 0 };
        ensure!(
            moments.len() == want_moments,
            "checkpoint has {} adam moment pairs, this optimizer wants {}",
            moments.len(),
            want_moments
        );
        if self.resident {
            // rebuild the executor-resident layout: weights first, then
            // interleaved (m, v) pairs in weight order
            let mut full: Vec<Tensor> = weights.to_vec();
            for (m, v) in moments {
                full.push(m.clone());
                full.push(v.clone());
            }
            self.lead.exec.restore_states(&full, opt_t);
            for slot in &self.others {
                let mut st = slot.state.lock().unwrap();
                let engine = st.engine.as_mut().expect("replica engine parked");
                engine.exec.restore_states(&full, opt_t);
            }
        } else {
            self.host_weights = weights.to_vec();
            self.host_moments = moments.to_vec();
            self.host_t = opt_t;
        }
        Ok(())
    }

    /// Whether weights + optimizer state live inside the executors.
    pub fn resident(&self) -> bool {
        self.resident
    }

    /// Bytes of executor-resident training state *per replica* (0 on the
    /// fallback path); each replica carries its own full copy.
    pub fn resident_state_bytes(&self) -> u64 {
        self.lead.program.resident_state_bytes()
    }

    /// Compiler statistics of the lead replica's step program (replica
    /// programs differ only in which lanes they own).
    pub fn program_report(&self) -> ProgramReport {
        analyze_program(&self.lead.program)
    }

    /// Total kernel-thread budget across the set (the parent budget that
    /// was split `budget / replicas` per replica pool).
    pub fn threads(&self) -> usize {
        self.budget
    }

    /// Kernel threads each replica's pool runs on.
    pub fn threads_per_replica(&self) -> usize {
        self.per_replica_threads
    }

    pub fn replicas(&self) -> usize {
        self.n_replicas
    }

    /// Lanes in the canonical function-dimension decomposition.
    pub fn lanes(&self) -> usize {
        self.n_lanes
    }

    pub fn coord_dim(&self) -> usize {
        self.coord_dim
    }

    /// Graph build + compile time across all replica programs.
    pub fn compile_time(&self) -> Duration {
        self.compile_time
    }

    pub fn sched(&self) -> SchedMode {
        self.lead.exec.sched()
    }

    pub fn simd(&self) -> SimdLevel {
        self.lead.exec.simd()
    }

    /// Drain the lead replica's profile (replicas 1.. are drained by
    /// [`ReplicaSet::take_replica_profiles`]).
    pub fn take_profile(&mut self) -> Option<ProfileReport> {
        self.lead.exec.take_profile()
    }

    /// Drain the profiles of replicas 1.., in replica order (the lead's
    /// comes from [`ReplicaSet::take_profile`]); empty when profiling is
    /// off or the set is single-replica.
    pub fn take_replica_profiles(&mut self) -> Vec<ProfileReport> {
        let mut out = Vec::new();
        for slot in &self.others {
            let mut st = slot.state.lock().unwrap();
            let engine = st.engine.as_mut().expect("replica engine parked");
            if let Some(p) = engine.exec.take_profile() {
                out.push(p);
            }
        }
        out
    }
}

impl Drop for ReplicaSet {
    fn drop(&mut self) {
        for slot in &self.others {
            let mut st = slot.state.lock().unwrap();
            st.cmd = Cmd::Exit;
            drop(st);
            slot.cv.notify_all();
        }
        for handle in self.drivers.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Copy one engine's lane-major loss readback into the per-global-lane
/// staging table.
fn stash_losses(lane_losses: &mut [[f64; 3]], engine: &ReplicaEngine) {
    for (k, &lane) in engine.local_lanes.iter().enumerate() {
        let ls = &engine.losses[3 * k..3 * k + 3];
        lane_losses[lane] = [ls[0], ls[1], ls[2]];
    }
}
